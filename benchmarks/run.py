"""Benchmark harness entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable
headers) and writes JSON artifacts to experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

MODULES = [
    ("table2_accuracy", "Table 2: accuracy/PSNR/TPR vs tile size"),
    ("table3_strategies", "Table 3: tiling strategies under attacks"),
    ("table4_tile_sizes", "Table 4: strategies x tile sizes"),
    ("table5_bitlengths", "Table 5: payload length sweep"),
    ("fig6_throughput", "Fig 6: throughput vs batch"),
    ("fig7_latency", "Fig 7: latency vs batch"),
    ("fig8_breakdown", "Fig 8: optimization breakdown"),
    ("fig9_tile_ingest", "Fig 9: staged vs tile-first ingest"),
    ("fig10_decode", "Fig 10: unfused vs fused decode, "
                     "fp32/bf16/int8 x flat/tuned schedules"),
    ("fig11_online_serving",
     "Fig 11: online serving — offered load vs latency percentiles"),
    ("fig12_escalation",
     "Fig 12: adaptive multi-tile escalation under attacks"),
    ("fig13_cache",
     "Fig 13: content cache + SLO admission under Zipf load"),
    ("fig14_fleet",
     "Fig 14: fleet scaling (sustained qps vs replicas) + chaos arm"),
    ("alloc_adaptivity", "§3: stream-allocation adaptivity"),
    ("kernel_fusion", "App B.1: preprocess kernel fusion"),
    ("roofline", "§Roofline: per-stage achieved vs roofline FLOPs"),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived", flush=True)
    failures = []
    for mod_name, desc in MODULES:
        if args.only and args.only not in mod_name:
            continue
        print(f"# --- {mod_name}: {desc} ---", flush=True)
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            mod.main(quick=args.quick)
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            failures.append(mod_name)
            print(f"# {mod_name} FAILED: {type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}", flush=True)
        return 1
    print("# all benchmarks complete", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
