"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
experiments/dryrun/*.json.  Run after the sweep:

  PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import common


def recs(mesh, tag="baseline"):
    out = []
    for p in sorted(common.DRYRUN_DIR.glob(f"*__{mesh}__{tag}.json")):
        out.append(json.loads(p.read_text()))
    return out


def dryrun_section():
    lines = ["## §Dry-run", "",
             "Every (arch x shape) cell lowered + compiled with "
             "`.lower().compile()` on the production meshes. "
             "`mem/dev` = compiled per-device argument+temp bytes "
             "(CPU-backend buffer assignment; TPU layouts differ).", ""]
    for mesh, label in (("single", "16x16 single-pod (256 chips)"),
                        ("multi", "2x16x16 multi-pod (512 chips)")):
        rs = recs(mesh)
        n_ok = sum(r.get("status") == "ok" for r in rs)
        n_skip = sum(r.get("status") == "skipped" for r in rs)
        n_fail = len(rs) - n_ok - n_skip
        lines.append(f"### {label}: {n_ok} compiled, {n_skip} skipped "
                     f"(documented), {n_fail} failed")
        lines.append("")
        lines.append("| arch | shape | status | plan | mem/dev | "
                     "collectives (while-body-once) | compile s |")
        lines.append("|---|---|---|---|---|---|---|")
        for r in rs:
            if r.get("status") == "skipped":
                lines.append(f"| {r['arch']} | {r['shape']} | SKIP | "
                             f"{r.get('reason','')} | | | |")
                continue
            if r.get("status") != "ok":
                lines.append(f"| {r['arch']} | {r['shape']} | **FAIL** | "
                             f"{r.get('error','')[:60]} | | | |")
                continue
            p = r["plan"]
            plan = (f"fsdp={'Y' if p['fsdp'] else 'N'} "
                    f"micro={p['n_micro']}")
            m = r["real"]["memory"]
            mem = (m["argument_size_in_bytes"] or 0) + \
                (m["temp_size_in_bytes"] or 0)
            cc = r["real"]["coll_counts"]
            coll = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in sorted(cc.items()))
            lines.append(
                f"| {r['arch']} | {r['shape']} | ok | {plan} | "
                f"{mem/1e9:.2f}GB | {coll} | {r.get('compile_s','')} |")
        lines.append("")
    return "\n".join(lines)


def roofline_section():
    lines = ["## §Roofline (single-pod, baseline tag)", "",
             "Terms are seconds/step per the probe-derived method "
             "(DESIGN.md §Dry-run cost accounting): compute = "
             "FLOPs/(197 TF/s), memory = HBM bytes/(819 GB/s), "
             "collective = ring-transfer bytes/(50 GB/s/link). "
             "`useful` = MODEL_FLOPS / HLO_FLOPS (6*N_active*D train, "
             "2*N_active*D inference); `frac` = t_compute / max(terms) — "
             "the roofline fraction scored in §Perf.", ""]
    lines.append("| arch | shape | t_comp | t_mem | t_coll | dominant | "
                 "useful | frac | one-line diagnosis |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    diag = {
        "collective": "reshard/gather traffic dominates - see §Perf levers",
        "memory": "HBM streaming bound (weights/cache/activations)",
        "compute": "MXU-bound - at roofline",
    }
    for r in recs("single"):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | | | | SKIP | | | "
                         f"{r.get('reason','')[:46]} |")
            continue
        if r.get("status") != "ok":
            continue
        d = r["derived"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {d['t_compute_s']:.4f} | "
            f"{d['t_memory_s']:.4f} | {d['t_collective_s']:.4f} | "
            f"{d['dominant']} | {d['useful_flops_ratio']:.3f} | "
            f"{d['roofline_fraction']:.3f} | {diag[d['dominant']]} |")
    lines.append("")
    # multi-pod delta summary
    lines.append("### Multi-pod (2x16x16) deltas")
    lines.append("")
    lines.append("| arch | shape | t_coll single | t_coll multi | "
                 "pod-axis cost |")
    lines.append("|---|---|---|---|---|")
    singles = {(r["arch"], r["shape"]): r for r in recs("single")
               if r.get("status") == "ok"}
    for r in recs("multi"):
        if r.get("status") != "ok":
            continue
        key = (r["arch"], r["shape"])
        if key not in singles:
            continue
        a = singles[key]["derived"]["t_collective_s"]
        b = r["derived"]["t_collective_s"]
        lines.append(f"| {r['arch']} | {r['shape']} | {a:.4f} | {b:.4f} | "
                     f"{(b - a):+.4f}s |")
    return "\n".join(lines)


def bench_section():
    """Summaries of the experiments/bench JSON artifacts that carry an
    acceptance-style summary block (fig11 online serving, fig13 cache,
    fig14 fleet) — the serving-side counterpart of the dryrun/roofline
    tables."""
    lines = ["## §Bench — serving artifacts", ""]
    p = common.OUT_DIR / "BENCH_online.json"
    if p.exists():
        s = json.loads(p.read_text()).get("summary", {})
        lines.append(
            f"- fig11 sustained qps @ p95<="
            f"{s.get('latency_budget_ms')}ms: {s.get('sustained_qps')} "
            f"(qrmark/sequential = {s.get('qrmark_vs_sequential')})")
    p = common.OUT_DIR / "BENCH_cache.json"
    if p.exists():
        s = json.loads(p.read_text()).get("summary", {})
        lines.append(
            f"- fig13 content cache @ Zipf s={s.get('zipf_s')}: "
            f"hit_rate={s.get('hit_rate')}, mean "
            f"{s.get('mean_ms_nocache')}ms -> "
            f"{s.get('mean_ms_cache')}ms, interactive p95 "
            f"{s.get('interactive_p95_ms_nocache')}ms -> "
            f"{s.get('interactive_p95_ms_cache')}ms "
            f"(hit>=50%: {s.get('hit_rate_ge_50pct')}, "
            f"mean better: {s.get('mean_strictly_better')}, "
            f"p95 no worse: {s.get('interactive_p95_no_worse')})")
    p = common.OUT_DIR / "BENCH_fleet.json"
    if p.exists():
        s = json.loads(p.read_text()).get("summary", {})
        c = s.get("chaos", {})
        lines.append(
            f"- fig14 fleet sustained qps @ p95<="
            f"{s.get('latency_budget_ms')}ms: {s.get('sustained_qps')} "
            f"(monotonic 1->4: {s.get('monotonic_1_to_4')}); chaos "
            f"kill-one-replica: reroutes={c.get('reroutes')}, "
            f"all admitted completed: "
            f"{c.get('all_admitted_completed')}")
    if len(lines) == 2:
        lines.append("- no BENCH_*.json artifacts yet "
                     "(run `python -m benchmarks.run`)")
    return "\n".join(lines)


def main(quick=False):
    print(dryrun_section())
    print()
    print(roofline_section())
    print()
    print(optimized_section())
    print()
    print(bench_section())


def optimized_section():
    """Baseline vs opt3 (serving levers) for every cell with both tags."""
    import glob
    lines = ["## §Perf — optimized serving sweep (tag opt3-bf16acc)", "",
             "| arch | shape | base bound | opt bound | gain | opt dom |",
             "|---|---|---|---|---|---|"]
    for p in sorted(common.DRYRUN_DIR.glob(
            "*__single__opt3-bf16acc.json")):
        o = json.loads(p.read_text())
        if o.get("status") != "ok":
            continue
        bp = Path(str(p).replace("opt3-bf16acc", "baseline"))
        if not bp.exists():
            continue
        b = json.loads(bp.read_text())
        if b.get("status") != "ok":
            continue
        od, bd = o["derived"], b["derived"]
        ob = od["roofline_bound_s"]
        bb = bd["roofline_bound_s"]
        lines.append(f"| {o['arch']} | {o['shape']} | {bb:.4f}s | "
                     f"{ob:.4f}s | {bb/ob:.1f}x | {od['dominant']} |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
