"""Fig. 12 (repo-native): adaptive multi-tile escalation under attacks.

QRMark's headline tradeoff is that one-tile decoding buys speed but
costs accuracy whenever the selected tile lands on a flat or attacked
region.  Adaptive escalation (``DetectionConfig.escalate_tiles``) keeps
the single-tile fast path for the common case and, only when RS fails
(or the margin is thin), decodes additional non-colliding tiles and
accumulates soft bits between RS attempts.  This benchmark sweeps the
ATTACKS registry against three policies:

* ``single``    — the unchanged 1-tile pipeline (``escalate_tiles=1``);
* ``adaptive-k``— escalate on demand up to k tiles/image;
* ``always-k``  — decode all k tiles up front through the (b, k, 2)
  kernel form (``StageRegistry.decode_all_keyed``), combine, RS once —
  the accuracy ceiling at k tiles and the latency price adaptive
  escalation avoids.

Workload: the untrained-extractor fallback used by fig10 — encoder and
extractor share the spread-spectrum pattern bank, the noisy untrained
conv/head path is zeroed, so the correlation path decodes the embedded
codeword with a real margin and no trained artifact is needed.  Every
grid tile of each image carries the same RS codeword (the paper's
embedding layout), attacks run in normalized tile space, and detection
runs through the full pipeline (tile-first fused ingest -> fused decode
-> device RS).

Reported per (attack, policy): exact-message match rate, RS ok rate,
bit accuracy, mean tiles decoded per image (the latency unit: decode
work scales with tiles), measured wall seconds/image, and the
escalation rate.  A final serving section runs the same attacked stream
through ``DetectionServer`` with escalation on and snapshots its
metrics registry (escalation_rate / tiles_per_image / escalation
batches).  Writes ``experiments/bench/BENCH_escalation.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import tiling
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.extractor import (encoder_forward, init_encoder,
                                  init_extractor)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.core.transforms import ATTACKS

TILE, IMG = 16, 64
EMBED_RMS = 0.15        # calibrated so attacks leave partial per-tile
#                         evidence: strong enough that combining tiles
#                         recovers, weak enough that single tiles fail
QUICK_ATTACKS = ("none", "overlay_text", "blur", "resize_0.7", "jpeg_50")


def _workload(batch: int):
    """Watermarked [-1, 1] images + the corr-only detector (fig10's
    untrained fallback: tied pattern bank, conv/head path zeroed)."""
    from repro.data.pipeline import synth_image
    code = DEFAULT_CODE
    enc = init_encoder(jax.random.key(1), n_bits=code.codeword_bits,
                       channels=8, depth=2, tile=TILE)
    dec = init_extractor(jax.random.key(2), n_bits=code.codeword_bits,
                         channels=8, depth=2, tile=TILE,
                         patterns=enc["patterns"])
    dec["head"]["w"] = dec["head"]["w"] * 0.0
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))
    imgs = jnp.asarray(
        np.stack([synth_image(i, IMG) for i in range(batch)]),
        jnp.float32) / 127.5 - 1.0
    flat = tiling.grid_partition(imgs, TILE).reshape(-1, TILE, TILE, 3)
    xw, _ = encoder_forward(
        enc, flat,
        jnp.broadcast_to(cw, (flat.shape[0], code.codeword_bits)),
        embed_rms=EMBED_RMS)
    g = IMG // TILE
    xw = xw.reshape(batch, g, g, TILE, TILE, 3).transpose(
        0, 1, 3, 2, 4, 5).reshape(batch, IMG, IMG, 3)
    return dec, msg, np.asarray(xw), code


def _to_raw(x):
    """Normalized [-1, 1] -> the 0..255 raw domain the pipeline ingests
    (float, so the benchmark isolates attack damage from quantisation)."""
    return np.clip((x + 1.0) * 127.5, 0.0, 255.0).astype(np.float32)


def _cfg(k):
    return DetectionConfig(tile=TILE, img_size=IMG, resize_src=IMG,
                           mode="qrmark", rs_mode="device",
                           code=DEFAULT_CODE, escalate_tiles=k)


def _measure(call, raw):
    call(raw)                       # warmup: compiles every shape
    t0 = time.perf_counter()
    out = call(raw)
    return out, time.perf_counter() - t0


def _always_k(pipe, raw, key, k):
    """The always-k baseline: all k tiles through the (b, k, 2) kernel
    path, soft bits combined, one RS pass."""
    reg = pipe.stages
    keys = reg.image_keys(key, raw.shape[0])
    logits_k = reg.decode_all_keyed(raw, keys)          # (b, k, n)
    acc = jnp.sum(logits_k, axis=1)
    msg, ok, nc = reg.rs_correct(
        (np.asarray(acc) > 0).astype(np.int32))
    return {"message_bits": np.asarray(msg), "ok": np.asarray(ok),
            "logits": np.asarray(acc),
            "tiles_used": np.full(raw.shape[0], k, np.int32)}


def _row(attack, policy, k, out, msg, wall_s, b):
    match = np.all(out["message_bits"] == msg[None], axis=1)
    tiles = out.get("tiles_used", np.ones(b, np.int32))
    return {
        "attack": attack, "policy": policy, "k": k,
        "match_rate": round(float(match.mean()), 4),
        "ok_rate": round(float(np.asarray(out["ok"]).mean()), 4),
        "bit_acc": round(float(
            (out["message_bits"] == msg[None]).mean()), 4),
        "mean_tiles": round(float(tiles.mean()), 4),
        "escalation_rate": round(float((tiles > 1).mean()), 4),
        "wall_s_per_image": wall_s / b,
    }


def _serving_section(dec, msg, attacked, k):
    """Escalation through the online server: metrics-registry proof."""
    from repro.serving import BatcherConfig, DetectionServer
    srv = DetectionServer(
        _cfg(k), dec,
        batcher=BatcherConfig(max_batch=8, max_wait_ms=2.0)).start()
    try:
        handles = [srv.submit(attacked[i: i + 2],
                              key=jax.random.key(1000 + i))
                   for i in range(0, attacked.shape[0], 2)]
        for h in handles:
            h.result(600)
        stats = srv.stats()
    finally:
        srv.close()
    return {
        "k": k,
        "escalation_rate": stats["escalation_rate"],
        "escalation_batches": stats["escalation_batches"],
        "images_escalated": stats["counters"].get("images_escalated", 0),
        "tiles_per_image": stats.get("tiles_per_image"),
        "straggler_retries": stats["straggler_retries"],
    }


def main(quick: bool = False):
    b = 8 if quick else 16
    ks = (2,) if quick else (2, 4)
    attacks = QUICK_ATTACKS if quick else tuple(ATTACKS)
    dec, msg, xw, code = _workload(b)

    pipes = {1: DetectionPipeline(_cfg(1), dec)}
    for k in ks:
        pipes[k] = DetectionPipeline(_cfg(k), dec)
    key = jax.random.key(7)

    rows = []
    recovered = {k: [] for k in ks}
    for attack in attacks:
        attacked = _to_raw(np.asarray(ATTACKS[attack](jnp.asarray(xw))))
        out1, w1 = _measure(
            lambda r: pipes[1].detect_batch(r, key=key), attacked)
        base = _row(attack, "single", 1, out1, msg, w1, b)
        rows.append(base)
        for k in ks:
            outk, wk = _measure(
                lambda r, k=k: pipes[k].detect_batch(r, key=key),
                attacked)
            row = _row(attack, f"adaptive", k, outk, msg, wk, b)
            rows.append(row)
            if row["match_rate"] > base["match_rate"]:
                recovered[k].append(attack)
            common.emit(
                f"fig12/{attack}_k{k}", wk / b,
                f"match={base['match_rate']}->{row['match_rate']};"
                f"tiles={row['mean_tiles']};"
                f"esc_rate={row['escalation_rate']}")
        k = max(ks)
        outa, wa = _measure(
            lambda r: _always_k(pipes[k], r, key, k), attacked)
        rows.append(_row(attack, "always", k, outa, msg, wa, b))

    # online: the attacked stream that escalates the most
    worst = min((r for r in rows if r["policy"] == "single"),
                key=lambda r: r["match_rate"])["attack"]
    serving = _serving_section(
        dec, msg, _to_raw(np.asarray(ATTACKS[worst](jnp.asarray(xw)))),
        max(ks))

    k = max(ks)
    adaptive = [r for r in rows if r["policy"] == "adaptive"
                and r["k"] == k]
    summary = {
        "k_max": k,
        "attacks_recovered": recovered[k],
        "n_attacks_recovered": len(recovered[k]),
        "mean_tiles_adaptive": round(float(np.mean(
            [r["mean_tiles"] for r in adaptive])), 4),
        "mean_tiles_always": float(k),
        "sublinear_latency": bool(np.mean(
            [r["mean_tiles"] for r in adaptive]) < k),
        "serving": serving,
    }
    common.save_json("BENCH_escalation", {"rows": rows,
                                          "summary": summary})
    common.emit(
        "fig12/summary", 0.0,
        f"recovered={len(recovered[k])}/{len(attacks)} attacks at k={k};"
        f"mean_tiles={summary['mean_tiles_adaptive']} (always-k={k});"
        f"serving_esc_rate={serving['escalation_rate']:.3f}")
    for p in pipes.values():
        p.close()
    return rows, summary


if __name__ == "__main__":
    main()
