"""Paper Appendix B.1: kernel fusion for preprocessing.

On this CPU container the meaningful comparison is structural, the same
method as the dry-run: lower both versions and compare HLO op counts and
bytes accessed (the fusion removes intermediate HBM round-trips); wall
time in interpret mode is reported as an anecdote only."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.transforms import preprocess_reference
from repro.kernels.ops import fused_preprocess


def hlo_stats(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    n_ops = sum(1 for l in txt.splitlines()
                if " = " in l and "parameter(" not in l)
    return {"ops": n_ops,
            "bytes": float(cost.get("bytes accessed", 0)),
            "flops": float(cost.get("flops", 0))}


def main(quick: bool = False):
    b = 4 if quick else 16
    raw = jax.ShapeDtypeStruct((b, 320, 320, 3), jnp.uint8)
    unfused = hlo_stats(lambda r: preprocess_reference(r, resize=288,
                                                       crop=256), raw)
    fused = hlo_stats(lambda r: fused_preprocess(r, resize=288, crop=256),
                      raw)
    rows = [{"variant": "unfused", **unfused},
            {"variant": "fused_pallas", **fused},
            {"variant": "reduction",
             "ops": round(unfused["ops"] / max(fused["ops"], 1), 2),
             "bytes": round(unfused["bytes"] / max(fused["bytes"], 1), 2),
             "flops": round(unfused["flops"] / max(fused["flops"], 1), 2)}]
    # wall-clock anecdote (CPU interpret mode)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, (b, 320, 320, 3), dtype=np.uint8))
    t_un = common.timeit(
        jax.jit(lambda r: preprocess_reference(r, resize=288, crop=256)), x)
    t_fu = common.timeit(
        lambda r: fused_preprocess(r, resize=288, crop=256), x)
    common.emit("kernel_fusion/unfused", t_un,
                f"ops={unfused['ops']};bytes={unfused['bytes']:.0f}")
    common.emit("kernel_fusion/fused", t_fu,
                f"ops={fused['ops']};bytes={fused['bytes']:.0f};"
                f"bytes_reduction={rows[2]['bytes']}x")
    common.save_json("kernel_fusion", rows)
    return rows


if __name__ == "__main__":
    main()
