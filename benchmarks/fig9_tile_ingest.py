"""Fig. 9 (repo-native): staged vs tile-first ingest.

The staged qrmark ingest resizes/normalises the FULL image while the
decode stage consumes one l x l tile; the tile-first kernel
(``kernels.fused_tile_preprocess``) slices the interpolation matrices to
the selected tile before the matmuls, so ingest computes exactly the
decode input.  This benchmark quantifies that cut both ways:

* analytically — XLA ``cost_analysis()`` FLOPs / bytes-accessed of the
  two jitted ingest functions (interpret-mode Pallas lowers to plain
  HLO, so the numbers are the real op counts);
* empirically — wall time per call on this host.

Writes ``experiments/bench/BENCH_tile_ingest.json`` (a machine-readable
series for the perf trajectory; schema: one row per (img, tile) config
with staged/tile_first flops, bytes, wall seconds, and the ratios).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import tiling
from repro.data.pipeline import synth_image
from repro.kernels import ops as kops

# (img_size, tile, batch); raw input is img + 32 on a side
CONFIGS = ((256, 64, 8), (256, 128, 8), (128, 32, 16))
STRATEGY = "random_grid"


def build_ingest_fns(img: int, tile: int):
    resize = img + img // 8

    def staged(raw):
        return kops.fused_preprocess(raw, resize=resize, crop=img)

    def tile_first(raw, batch_key):
        keys = jax.vmap(
            lambda i: jax.random.fold_in(batch_key, i))(
                jnp.arange(raw.shape[0]))
        offs = tiling.tile_first_offsets(STRATEGY, keys, img_size=img,
                                         tile=tile)
        return kops.fused_tile_preprocess(raw, offs, resize=resize,
                                          crop=img, tile=tile)

    return jax.jit(staged), jax.jit(tile_first)


def main(quick: bool = False):
    configs = CONFIGS[:1] if quick else CONFIGS
    iters = 2 if quick else 5
    rows = []
    for img, tile, b in configs:
        if quick:
            b = min(b, 4)
        raw = jnp.asarray(np.stack(
            [synth_image(i, img + 32) for i in range(b)]))
        key = jax.random.key(0)
        staged, tile_first = build_ingest_fns(img, tile)

        s_flops, s_bytes = common.cost_analysis(staged, raw)
        t_flops, t_bytes = common.cost_analysis(tile_first, raw, key)
        s_wall = common.timeit(staged, raw, iters=iters)
        t_wall = common.timeit(tile_first, raw, key, iters=iters)

        red = s_flops / t_flops if t_flops else float("inf")
        speed = s_wall / t_wall if t_wall else float("inf")
        rows.append({
            "img": img, "tile": tile, "batch": b, "raw": img + 32,
            "strategy": STRATEGY,
            "staged": {"flops": s_flops, "bytes": s_bytes,
                       "wall_s": s_wall},
            "tile_first": {"flops": t_flops, "bytes": t_bytes,
                           "wall_s": t_wall},
            "flop_reduction": round(red, 2),
            "bytes_reduction": round(s_bytes / t_bytes, 2) if t_bytes
            else None,
            "wall_speedup": round(speed, 2),
        })
        common.emit(
            f"fig9/img{img}_tile{tile}", t_wall,
            f"flops_staged={s_flops:.3g};flops_tile_first={t_flops:.3g};"
            f"flop_reduction={red:.2f}x;wall_speedup={speed:.2f}x")
    common.save_json("BENCH_tile_ingest", rows)
    return rows


if __name__ == "__main__":
    main()
