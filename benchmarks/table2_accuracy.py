"""Paper Table 2: bit accuracy / adversarial accuracy / PSNR / TPR across
tile sizes, QRMark (tiled + RS) vs the full-image baseline.

Measured on the trained tile extractors; TPR at FPR 1e-6 uses the exact
binomial threshold over codeword bits (paper's statistical test).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import transforms
from repro.core.train_extractor import evaluate


def tpr_at_fpr(bit_acc: float, n_bits: int, fpr: float = 1e-6,
               trials: int = 20000, seed: int = 0) -> float:
    """Monte-Carlo TPR of the binomial match test at threshold tau(fpr),
    with per-bit error rate (1 - bit_acc)."""
    from math import comb
    probs = np.array([comb(n_bits, i) for i in range(n_bits + 1)], float)
    probs /= probs.sum()
    cum = np.cumsum(probs[::-1])[::-1]
    tau = int(np.argmax(cum <= fpr))
    rng = np.random.default_rng(seed)
    agree = rng.binomial(n_bits, bit_acc, size=trials)
    return float((agree >= tau).mean())


def main(quick: bool = False):
    rows = []
    n_img = 48 if quick else 128
    attacks = ("none",) + transforms.STABLE_SIG_ATTACKS
    for tile in common.trained_tiles():
        params, cfg = common.load_extractor(tile)
        ev = evaluate(params, cfg, n_images=n_img, attacks=attacks)
        clean = ev["none"]
        adv = [ev[a]["bit_acc"] for a in transforms.STABLE_SIG_ATTACKS]
        n_bits = cfg.code.codeword_bits
        row = {
            "tile": tile,
            "bit_acc": round(clean["bit_acc"], 3),
            "bit_acc_adv": round(float(np.mean(adv)), 3),
            "psnr": round(clean["psnr"], 2),
            "tpr_1e-6": round(tpr_at_fpr(clean["bit_acc"], n_bits), 3),
            "rs_word_acc": round(clean.get("rs_word_acc", 0.0), 3),
        }
        rows.append(row)
        common.emit(f"table2/tile{tile}", 0.0,
                    f"bit_acc={row['bit_acc']};adv={row['bit_acc_adv']};"
                    f"psnr={row['psnr']};tpr={row['tpr_1e-6']}")
    common.save_json("table2_accuracy", rows)
    return rows


if __name__ == "__main__":
    main()
