"""Roofline table: reads the dry-run JSON records (experiments/dryrun/)
and prints per-(arch x shape x mesh) compute/memory/collective terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS ratio — the §Roofline
deliverable."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks import common


def load_records(mesh: str = "single", tag: str = "baseline"):
    recs = []
    for p in sorted(common.DRYRUN_DIR.glob(f"*__{mesh}__{tag}.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_row(r):
    if r.get("status") == "skipped":
        return (f"{r['arch']:26s} {r['shape']:12s} SKIP: "
                f"{r.get('reason', '')[:48]}")
    if r.get("status") != "ok":
        return (f"{r['arch']:26s} {r['shape']:12s} FAILED: "
                f"{r.get('error', '')[:60]}")
    d = r["derived"]
    return (f"{r['arch']:26s} {r['shape']:12s} "
            f"tc={d['t_compute_s']:9.4f}s tm={d['t_memory_s']:9.4f}s "
            f"tx={d['t_collective_s']:9.4f}s dom={d['dominant']:10s} "
            f"useful={d['useful_flops_ratio']:6.3f} "
            f"roofline_frac={d['roofline_fraction']:5.3f}")


def main(quick: bool = False, mesh: str = "single", tag: str = "baseline"):
    recs = load_records(mesh, tag)
    if not recs:
        print(f"roofline: no dry-run records for mesh={mesh} tag={tag}; "
              "run repro.launch.dryrun first", flush=True)
        return []
    print(f"--- roofline ({mesh}-pod mesh, tag={tag}) ---", flush=True)
    rows = []
    for r in recs:
        print(fmt_row(r), flush=True)
        if r.get("status") == "ok":
            d = r["derived"]
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "mesh": r["mesh"], **{k: d[k] for k in (
                             "t_compute_s", "t_memory_s", "t_collective_s",
                             "dominant", "useful_flops_ratio",
                             "roofline_fraction", "model_flops")}})
            common.emit(
                f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                d["roofline_bound_s"],
                f"dom={d['dominant']};frac={d['roofline_fraction']:.3f};"
                f"useful={d['useful_flops_ratio']:.3f}")
    common.save_json(f"roofline_{mesh}_{tag}", rows)
    return rows


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    main(mesh=mesh)
