"""Roofline table: per-stage detection-pipeline achieved vs roofline
FLOP rates (the §Roofline deliverable, re-anchored).

Earlier revisions of this table read the LLM dry-run records left over
from the seed scaffold (``experiments/dryrun``) — stale numbers about a
model this repo no longer runs.  This module measures the *detection
pipeline itself*, stage by stage, live on this host:

* ``peak`` — the machine's achievable dense-GEMM rate, measured with a
  large fp32 matmul (the roofline everything else is a fraction of; on
  CPU this is what Eigen reaches, on TPU the MXU rate);
* ``ingest`` — the tile-first fused preprocess kernel.  Model FLOPs are
  analytic: the two per-channel interpolation matmuls the kernel
  actually runs, (l, H) @ (H, W) @ (W, l) per image (sliced
  interpolation matrices; see ``kernels/fused_tile_preprocess.py``);
* ``decode`` — the fused extractor kernel (flat schedule, plus the
  tuned blocked schedule when the autotune cache has a winner).  Model
  FLOPs are analytic: the nine-tap conv matmuls + to_bits + head +
  correlation bank;
* ``rs`` — the batched Berlekamp-Welch kernel.  GF(2^m) arithmetic is
  table lookups and XORs, not float math, so there is no analytic FLOP
  model; its row uses the XLA ``cost_analysis`` count (basis "hlo") and
  its roofline fraction is reported on that basis only.

Each row reports achieved GFLOP/s (model FLOPs / measured wall) and
``roofline_fraction`` = achieved / peak.  When
``experiments/bench/BENCH_decode.json`` exists (fig10 output), the
decode rows are cross-referenced against its wall numbers so the two
tables stay mutually consistent; when absent, a hint is printed.

Writes ``experiments/bench/BENCH_roofline.json``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common

# representative detection config: fig10's primary decode point riding
# on a serve.py-shaped ingest (raw = img + 32)
TILE, BATCH = 64, 8
IMG, RAW = 128, 160
CHANNELS, DEPTH = 64, 7


def measure_peak_gemm(n: int = 768, iters: int = 5) -> dict:
    """Measured dense fp32 GEMM rate — the roofline ceiling."""
    a = jnp.asarray(np.random.default_rng(0).normal(
        size=(n, n)).astype(np.float32))
    f = jax.jit(lambda x: x @ x)
    wall = common.timeit(f, a, iters=iters, warmup=2)
    flops = 2.0 * n ** 3
    return {"stage": "peak", "wall_s": wall, "model_flops": flops,
            "achieved_gflops": flops / wall / 1e9, "basis": "model",
            "note": f"dense fp32 {n}^3 GEMM"}


def ingest_model_flops(tile: int, raw: int, batch: int) -> float:
    """Per-batch analytic FLOPs of tile-first ingest: two sliced
    interpolation matmuls per channel per image —
    (l, H) @ (H, W) then (l, W) @ (W, l)."""
    per_image = 3 * (2.0 * tile * raw * raw + 2.0 * tile * tile * raw)
    return batch * per_image


def decode_model_flops(tile: int, batch: int, channels: int, depth: int,
                       n_bits: int) -> float:
    """Per-batch analytic FLOPs of the fused decode: nine-tap conv
    matmuls (layer 0 reads 3 input channels), to_bits, GAP-head and the
    correlation bank."""
    l2 = float(tile * tile)
    conv = 2.0 * 9 * l2 * (3 * channels
                           + (depth - 1) * channels * channels
                           + channels * n_bits)
    head = 2.0 * n_bits * n_bits
    corr = 2.0 * l2 * 3 * n_bits + 9 * l2 * 3  # contraction + box blur
    return batch * (conv + head + corr)


def _stage_row(name, wall, model_flops, peak_gflops, *, hlo_flops=None,
               basis="model", note=""):
    flops = model_flops if basis == "model" else hlo_flops
    achieved = flops / wall / 1e9 if wall else 0.0
    return {
        "stage": name, "wall_s": wall,
        "model_flops": model_flops, "hlo_flops": hlo_flops,
        "achieved_gflops": achieved,
        "roofline_fraction": achieved / peak_gflops if peak_gflops
        else 0.0,
        "basis": basis, "note": note,
    }


def main(quick: bool = False):
    from repro.core.extractor import init_extractor, pack_params
    from repro.core.rs.codec import DEFAULT_CODE
    from repro.core import tiling
    from repro.data.pipeline import synth_image
    from repro.kernels import autotune as autotune_lib
    from repro.kernels import ops as kops

    tile, batch = (TILE, 4) if quick else (TILE, BATCH)
    iters = 2 if quick else 4
    code = DEFAULT_CODE
    n_bits = code.codeword_bits

    print(f"--- roofline: detection pipeline stages "
          f"(tile={tile} batch={batch} backend="
          f"{jax.default_backend()}) ---", flush=True)

    peak = measure_peak_gemm(512 if quick else 768, iters=iters)
    peak_gflops = peak["achieved_gflops"]
    rows = [peak]
    print(f"peak GEMM: {peak_gflops:8.2f} GFLOP/s ({peak['note']})",
          flush=True)

    # -- ingest: tile-first fused preprocess ---------------------------
    raw = np.stack([synth_image(i, RAW) for i in range(batch)])
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(0), i)
                    )(jnp.arange(batch))
    offs = tiling.tile_first_offsets("random", keys, img_size=IMG,
                                     tile=tile)
    ingest = jax.jit(lambda r, o: kops.fused_tile_preprocess(
        r, o, resize=IMG + IMG // 8, crop=IMG, tile=tile))
    wall = common.timeit(ingest, raw, offs, iters=iters)
    hlo_fl, _ = common.cost_analysis(ingest, raw, offs)
    rows.append(_stage_row(
        "ingest", wall, ingest_model_flops(tile, RAW, batch),
        peak_gflops, hlo_flops=hlo_fl,
        note="tile-first fused preprocess (sliced interp matmuls)"))

    # -- decode: fused extractor, flat + tuned schedule ----------------
    params = init_extractor(jax.random.key(2), n_bits=n_bits,
                            channels=CHANNELS, depth=DEPTH, tile=tile)
    pk32 = pack_params(params, "fp32")
    tiles = jnp.asarray(np.random.default_rng(0).uniform(
        -1, 1, (batch, tile, tile, 3)).astype(np.float32))
    dec_model = decode_model_flops(tile, batch, CHANNELS, DEPTH, n_bits)
    flat = jax.jit(lambda t: kops.fused_extractor(t, pk32))
    wall = common.timeit(flat, tiles, iters=iters)
    # the fused graph lowers to a grid loop — cost_analysis counts the
    # body (one image) once; scale to the batch for the hlo basis
    hlo_fl, _ = common.cost_analysis(flat, tiles)
    rows.append(_stage_row(
        "decode_flat", wall, dec_model, peak_gflops,
        hlo_flops=hlo_fl * batch,
        note="fused extractor, flat schedule, fp32"))

    cache_path = common.REPO / "experiments" / "autotune" / \
        "decode_schedules.json"
    key = autotune_lib.schedule_key(
        backend=jax.default_backend(), dtype="fp32", tile=tile,
        channels=CHANNELS, depth=DEPTH, n_bits=n_bits)
    sched = autotune_lib.cache_lookup(
        autotune_lib.load_cache(cache_path), key)
    if sched is not None:
        tuned = jax.jit(lambda t: kops.fused_extractor(
            t, pk32, schedule=sched))
        wall_t = common.timeit(tuned, tiles, iters=iters)
        rows.append(_stage_row(
            "decode_tuned", wall_t, dec_model, peak_gflops,
            note=f"fused extractor, tuned schedule "
                 f"{sched.to_string()}, fp32"))
    else:
        print(f"roofline: no tuned schedule cached for {key} "
              f"(run `python -m repro.kernels.autotune` or fig10 "
              f"first); decode_tuned row skipped", flush=True)

    # -- rs: batched Berlekamp-Welch (hlo basis) -----------------------
    bits = jnp.asarray(np.random.default_rng(1).integers(
        0, 2, (batch, n_bits)).astype(np.int32))
    rs = jax.jit(lambda b: kops.rs_decode(b, code=code))
    wall = common.timeit(rs, bits, iters=iters)
    hlo_fl, _ = common.cost_analysis(rs, bits)
    rows.append(_stage_row(
        "rs", wall, None, peak_gflops, hlo_flops=hlo_fl, basis="hlo",
        note="GF(16) Berlekamp-Welch: table/XOR work, no float model; "
             "fraction on the XLA cost_analysis basis"))

    # -- cross-reference fig10's decode walls --------------------------
    bench_decode = common.OUT_DIR / "BENCH_decode.json"
    if bench_decode.exists():
        try:
            recs = json.loads(bench_decode.read_text())
            rec = next((r for r in recs if r.get("tile") == tile), None)
            if rec is not None:
                w = rec["fused_fp32"]["wall_s"]
                rows.append(_stage_row(
                    "decode_flat_fig10", w,
                    decode_model_flops(tile, rec["batch"], CHANNELS,
                                       DEPTH, n_bits),
                    peak_gflops,
                    note="fig10's measured flat-fp32 wall, for "
                         "cross-checking the live row"))
        except (json.JSONDecodeError, KeyError) as e:
            print(f"roofline: could not cross-reference "
                  f"{bench_decode}: {e}", flush=True)
    else:
        print("roofline: experiments/bench/BENCH_decode.json not found "
              "— run `python -m benchmarks.run --only fig10` (or the "
              "full benchmarks.run) to generate the decode records "
              "this table cross-references", flush=True)

    for r in rows[1:]:
        frac = r["roofline_fraction"]
        print(f"{r['stage']:18s} wall={r['wall_s'] * 1e3:9.2f}ms "
              f"achieved={r['achieved_gflops']:8.3f} GFLOP/s "
              f"frac={frac:6.4f} ({r['basis']})", flush=True)
        common.emit(f"roofline/{r['stage']}", r["wall_s"],
                    f"achieved_gflops={r['achieved_gflops']:.3f};"
                    f"roofline_frac={frac:.4f};basis={r['basis']}")
    common.save_json("BENCH_roofline", rows)
    return rows


if __name__ == "__main__":
    main()
