"""Paper Table 3: bit accuracy of the three tiling strategies under
attacks (none / crop 0.1 / crop 0.5 / resize 0.5 / blur / brightness 2 /
contrast 2)."""
from __future__ import annotations

from benchmarks import common
from repro.core.tiling import STRATEGIES
from repro.core.train_extractor import evaluate

ATTACKS = ("none", "crop_0.1", "crop_0.5", "resize_0.5", "blur",
           "brightness_2", "contrast_2")


def main(quick: bool = False, tile: int = 32):
    loaded = common.load_extractor(tile)
    if loaded is None:
        tiles = common.trained_tiles()
        if not tiles:
            print("table3: no trained extractor; run "
                  "examples/train_extractor.py first", flush=True)
            return []
        tile = tiles[0]
        loaded = common.load_extractor(tile)
    params, cfg = loaded
    n_img = 32 if quick else 96
    rows = []
    for strat in STRATEGIES:
        ev = evaluate(params, cfg, n_images=n_img, attacks=ATTACKS,
                      strategy=strat)
        row = {"strategy": strat}
        row.update({a: round(ev[a]["bit_acc"], 3) for a in ATTACKS})
        rows.append(row)
        common.emit(f"table3/{strat}", 0.0,
                    ";".join(f"{a}={row[a]}" for a in ATTACKS))
    common.save_json("table3_strategies", rows)
    return rows


if __name__ == "__main__":
    main()
