"""Fig. 10 (repo-native): decode across precisions and kernel schedules.

After the tile-first ingest cut (fig9) the decode stage — the 7-block
extractor conv stack + GAP/head + correlation bank — is the dominant
hot-path cost.  ``kernels.fused_extractor`` runs the whole forward in
one Pallas launch per tile batch on pre-packed weights; this benchmark
sweeps the full precision ladder x kernel schedule matrix:

* ``unfused``       — ``extractor_forward`` as a plain jitted XLA graph
  (im2col matmuls materialised between every block);
* precision rungs (packed-weight dtype): ``fp32`` (bit-identical to
  unfused by construction — asserted here on BOTH schedules), ``bf16``
  (bf16 MXU inputs, fp32 accumulation), ``int8`` (per-channel weight
  scales baked in at pack time, per-row activation quantization, int32
  accumulation — the TPU-oriented bottom rung; on this CPU host XLA
  has no fast int8 GEMM, so its wall time is a correctness datapoint,
  not a speedup);
* schedules: ``flat`` (grid=(b,), one image per step) and ``tuned``
  (the blocked kernel at the autotune winner for this
  backend/dtype/tile key — padded-activation scratch, flat-norm
  epilogue, channel-tiled accumulator; ``kernels/autotune.py``, cache
  under ``experiments/autotune/``).

Numbers reported per (tile, batch) config: ``wall_s`` per variant,
cost_analysis flops/bytes for the flat variants (NB fused graphs lower
to a grid loop whose body cost_analysis counts once — fused flops are
per grid step; ``flops_per_image`` normalises), wall speedups vs both
the unfused graph and the flat fp32 kernel, and — per reduced-precision
rung — ``bit_agreement`` (logit signs vs fp32) and
``decision_agreement`` (identical RS ``message_bits``/``ok``) on a
margin-bearing workload: codewords embedded through the tied
spread-spectrum pattern bank, the deployment distribution where
quantization error is far from the bit threshold.

Writes ``experiments/bench/BENCH_decode.json`` (perf-trajectory series).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.extractor import (encoder_forward, extractor_forward,
                                  init_encoder, init_extractor,
                                  pack_params)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.kernels import autotune as autotune_lib
from repro.kernels import ops as kops

# (tile, batch); extractor at paper scale: 64 channels x 7 blocks
CONFIGS = ((64, 8), (32, 16))
CHANNELS, DEPTH = 64, 7
DTYPES = ("fp32", "bf16", "int8")

AUTOTUNE_CACHE = common.REPO / "experiments" / "autotune" / \
    "decode_schedules.json"


def _workload(tile: int, batch: int):
    """Watermarked tiles + the extractor that decodes them: encoder and
    extractor share the spread-spectrum pattern bank, so bit logits
    carry a real margin (the deployment regime for the reduced-precision
    rungs)."""
    from repro.data.pipeline import synth_image
    code = DEFAULT_CODE
    enc = init_encoder(jax.random.key(1), n_bits=code.codeword_bits,
                       channels=8, depth=2, tile=tile)
    params = init_extractor(jax.random.key(2), n_bits=code.codeword_bits,
                            channels=CHANNELS, depth=DEPTH, tile=tile,
                            patterns=enc["patterns"])
    # weight the correlation path like a trained detector would: the
    # untrained conv stack is pure noise here, and the benchmark needs
    # the deployment property (margined logits), not trained accuracy
    params["corr_scale"] = params["corr_scale"] * 4.0
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))
    imgs = jnp.asarray(np.stack([synth_image(i, tile)
                                 for i in range(batch)]),
                       jnp.float32) / 127.5 - 1.0
    tiles, _ = encoder_forward(
        enc, imgs, jnp.broadcast_to(cw, (batch, code.codeword_bits)))
    return params, tiles, code


def _tuned_schedule(packed, tile, batch, dtype, quick):
    """The autotune winner for this key (tiny cached sweep on a miss)."""
    return autotune_lib.autotune(
        packed, tile=tile, batch=batch, dtype=dtype,
        cache_path=AUTOTUNE_CACHE, iters=2 if quick else 3,
        quick=True, log=lambda *a, **k: None)


def main(quick: bool = False):
    configs = CONFIGS[:1] if quick else CONFIGS
    iters = 2 if quick else 6
    rows = []
    for tile, batch in configs:
        if quick:
            batch = min(batch, 4)
        params, tiles, code = _workload(tile, batch)
        unfused = jax.jit(lambda t: extractor_forward(params, t))
        u_fl, u_by = common.cost_analysis(unfused, tiles)
        u_wall = common.timeit(unfused, tiles, iters=iters)
        lu = np.asarray(unfused(tiles))
        dev_rs = jax.jit(lambda b: kops.rs_decode(b, code=code))

        def rs_of(logits):
            r = dev_rs((jnp.asarray(logits) > 0).astype(jnp.int32))
            return np.asarray(r["message_bits"]), np.asarray(r["ok"])

        m32 = ok32 = l32 = None
        row = {
            "tile": tile, "batch": batch,
            "channels": CHANNELS, "depth": DEPTH,
            "unfused": {"flops": u_fl, "bytes": u_by, "wall_s": u_wall,
                        "flops_per_image": u_fl / batch},
        }
        for dtype in DTYPES:
            pk = pack_params(params, dtype)
            sched = _tuned_schedule(pk, tile, batch, dtype, quick)
            flat = jax.jit(lambda t, _pk=pk: kops.fused_extractor(
                t, _pk))
            sched_str = "flat" if sched is None else sched.to_string()
            tuned = jax.jit(lambda t, _pk=pk, _s=sched:
                            kops.fused_extractor(t, _pk, schedule=_s))
            f_fl, f_by = common.cost_analysis(flat, tiles)
            f_wall = common.timeit(flat, tiles, iters=iters)
            t_wall = common.timeit(tuned, tiles, iters=iters)
            lf = np.asarray(flat(tiles))
            lt = np.asarray(tuned(tiles))
            if dtype == "fp32":
                # THE fp32 bit-identity contract, on both schedules
                assert np.array_equal(lf, lu), \
                    "fused fp32 decode (flat schedule) must be " \
                    "bit-identical to extractor_forward"
                assert np.array_equal(lt, lu), \
                    "fused fp32 decode (tuned blocked schedule) must " \
                    "be bit-identical to extractor_forward"
                l32 = lf
                m32, ok32 = rs_of(l32)
            row[f"fused_{dtype}"] = {
                "dtype": dtype, "schedule": "flat",
                "flops": f_fl, "bytes": f_by, "wall_s": f_wall,
                "flops_per_image": f_fl,
            }
            row[f"fused_{dtype}_tuned"] = {
                "dtype": dtype, "schedule": sched_str,
                "wall_s": t_wall,
                "wall_speedup_vs_flat": round(f_wall / t_wall, 3),
            }
            if dtype != "fp32":
                md, okd = rs_of(lf)
                row[f"bit_agreement_{dtype}"] = round(
                    float(((lf > 0) == (l32 > 0)).mean()), 5)
                row[f"decision_agreement_{dtype}"] = float(np.mean(
                    np.all(md == m32, axis=1) & (okd == ok32)))
                # flat vs tuned must agree bitwise within a dtype too
                # (same quantization, same accumulation order)
                row[f"{dtype}_schedule_bit_identical"] = bool(
                    np.array_equal(lf, lt))

        f32, t32 = row["fused_fp32"], row["fused_fp32_tuned"]
        row.update({
            "flop_reduction_cost_analysis":
                round(u_fl / f32["flops"], 2) if f32["flops"] else None,
            "mxu_effective_flop_reduction_bf16":
                round((u_fl / batch) / (row["fused_bf16"]["flops"] / 2.0),
                      2) if row["fused_bf16"]["flops"] else None,
            "wall_speedup_fp32": round(u_wall / f32["wall_s"], 2),
            "wall_speedup_bf16": round(
                u_wall / row["fused_bf16"]["wall_s"], 2),
            # the headline schedule number: tuned blocked vs flat, fp32
            "wall_speedup_tuned_fp32": round(
                f32["wall_s"] / t32["wall_s"], 3),
            "tuned_schedule_fp32": t32["schedule"],
            "fp32_bit_identical": True,   # asserted above, both schedules
        })
        rows.append(row)
        common.emit(
            f"fig10/tile{tile}_b{batch}", t32["wall_s"],
            f"wall_speedup_fp32={row['wall_speedup_fp32']}x;"
            f"wall_speedup_tuned_fp32={row['wall_speedup_tuned_fp32']}x"
            f"({t32['schedule']});"
            f"wall_speedup_bf16={row['wall_speedup_bf16']}x;"
            f"bit_agree_bf16={row['bit_agreement_bf16']};"
            f"bit_agree_int8={row['bit_agreement_int8']};"
            f"decision_agree_int8={row['decision_agreement_int8']}")
    common.save_json("BENCH_decode", rows)
    return rows


if __name__ == "__main__":
    main()
