"""Fig. 10 (repo-native): unfused vs fused decode, fp32 vs bf16.

After the tile-first ingest cut (fig9) the decode stage — the 7-block
extractor conv stack + GAP/head + correlation bank — is the dominant
hot-path cost.  ``kernels.fused_extractor`` runs the whole forward in
one Pallas launch per tile batch on pre-packed weights, with a bf16 MXU
compute path.  This benchmark quantifies the three variants:

* ``unfused``    — ``extractor_forward`` as a plain jitted XLA graph
  (im2col matmuls materialised between every block);
* ``fused_fp32`` — the kernel on an fp32 pack (bit-identical to
  unfused by construction — asserted here);
* ``fused_bf16`` — the kernel on a bf16 pack: bf16 matmul inputs, fp32
  accumulation and epilogue.

Numbers reported per (tile, batch) config:

* ``flops`` / ``bytes`` — XLA ``cost_analysis()`` of each jitted graph.
  NB the fused graphs lower to a grid *loop*, whose body cost_analysis
  counts once — i.e. fused flops are per grid step (= per image), while
  unfused flops cover the whole batch; ``flops_per_image`` normalises
  both.  The arithmetic is intentionally identical per image — fusion
  wins on memory traffic and launches, bf16 on MXU rate;
* ``mxu_effective_flops_per_image`` — per-image flops scaled by the MXU
  dtype throughput (bf16 runs the 128x128 systolic array at 2x fp32),
  the TPU-cost view of the precision policy;
* ``wall_s`` — measured per call on this host (CPU interpret mode);
* ``bit_agreement`` (bf16 vs fp32 logit signs) and
  ``decision_agreement`` (identical RS ``message_bits``/``ok``) on a
  margin-bearing workload: codewords embedded through the tied
  spread-spectrum pattern bank, the deployment distribution where bf16
  error is far from the bit threshold.

Writes ``experiments/bench/BENCH_decode.json`` (perf-trajectory series).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.extractor import (encoder_forward, extractor_forward,
                                  init_encoder, init_extractor,
                                  pack_params)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.kernels import ops as kops

# (tile, batch); extractor at paper scale: 64 channels x 7 blocks
CONFIGS = ((64, 8), (32, 16))
CHANNELS, DEPTH = 64, 7


def _workload(tile: int, batch: int):
    """Watermarked tiles + the extractor that decodes them: encoder and
    extractor share the spread-spectrum pattern bank, so bit logits
    carry a real margin (the deployment regime for the bf16 policy)."""
    from repro.data.pipeline import synth_image
    code = DEFAULT_CODE
    enc = init_encoder(jax.random.key(1), n_bits=code.codeword_bits,
                       channels=8, depth=2, tile=tile)
    params = init_extractor(jax.random.key(2), n_bits=code.codeword_bits,
                            channels=CHANNELS, depth=DEPTH, tile=tile,
                            patterns=enc["patterns"])
    # weight the correlation path like a trained detector would: the
    # untrained conv stack is pure noise here, and the benchmark needs
    # the deployment property (margined logits), not trained accuracy
    params["corr_scale"] = params["corr_scale"] * 4.0
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))
    imgs = jnp.asarray(np.stack([synth_image(i, tile)
                                 for i in range(batch)]),
                       jnp.float32) / 127.5 - 1.0
    tiles, _ = encoder_forward(
        enc, imgs, jnp.broadcast_to(cw, (batch, code.codeword_bits)))
    return params, tiles, code


def main(quick: bool = False):
    configs = CONFIGS[:1] if quick else CONFIGS
    iters = 2 if quick else 4
    rows = []
    for tile, batch in configs:
        if quick:
            batch = min(batch, 4)
        params, tiles, code = _workload(tile, batch)
        pk32 = pack_params(params, "fp32")
        pk16 = pack_params(params, "bf16")
        unfused = jax.jit(lambda t: extractor_forward(params, t))
        fused32 = jax.jit(lambda t: kops.fused_extractor(t, pk32))
        fused16 = jax.jit(lambda t: kops.fused_extractor(t, pk16))

        u_fl, u_by = common.cost_analysis(unfused, tiles)
        f_fl, f_by = common.cost_analysis(fused32, tiles)
        h_fl, h_by = common.cost_analysis(fused16, tiles)
        u_wall = common.timeit(unfused, tiles, iters=iters)
        f_wall = common.timeit(fused32, tiles, iters=iters)
        h_wall = common.timeit(fused16, tiles, iters=iters)

        l32 = np.asarray(fused32(tiles))
        l16 = np.asarray(fused16(tiles))
        lu = np.asarray(unfused(tiles))
        assert np.array_equal(l32, lu), \
            "fused fp32 decode must be bit-identical to extractor_forward"
        bit_agree = float(((l16 > 0) == (l32 > 0)).mean())
        dev_rs = jax.jit(lambda b: kops.rs_decode(b, code=code))
        r32 = dev_rs((jnp.asarray(l32) > 0).astype(jnp.int32))
        r16 = dev_rs((jnp.asarray(l16) > 0).astype(jnp.int32))
        decision_agree = float(np.mean(
            np.all(np.asarray(r32["message_bits"]) ==
                   np.asarray(r16["message_bits"]), axis=1) &
            (np.asarray(r32["ok"]) == np.asarray(r16["ok"]))))

        # fused graphs lower to a grid loop: cost_analysis counts the
        # body (one image) once; normalise both views per image
        row = {
            "tile": tile, "batch": batch,
            "channels": CHANNELS, "depth": DEPTH,
            "unfused": {"flops": u_fl, "bytes": u_by, "wall_s": u_wall,
                        "flops_per_image": u_fl / batch},
            "fused_fp32": {"flops": f_fl, "bytes": f_by,
                           "wall_s": f_wall, "flops_per_image": f_fl,
                           "mxu_effective_flops_per_image": f_fl},
            "fused_bf16": {"flops": h_fl, "bytes": h_by,
                           "wall_s": h_wall, "flops_per_image": h_fl,
                           "mxu_effective_flops_per_image": h_fl / 2.0},
            "flop_reduction_cost_analysis":
                round(u_fl / f_fl, 2) if f_fl else None,
            "mxu_effective_flop_reduction_bf16":
                round((u_fl / batch) / (h_fl / 2.0), 2) if h_fl else None,
            "wall_speedup_fp32": round(u_wall / f_wall, 2) if f_wall
            else None,
            "wall_speedup_bf16": round(u_wall / h_wall, 2) if h_wall
            else None,
            "bit_agreement_bf16": round(bit_agree, 5),
            "decision_agreement_bf16": decision_agree,
            "fp32_bit_identical": True,
        }
        rows.append(row)
        common.emit(
            f"fig10/tile{tile}_b{batch}", h_wall,
            f"wall_speedup_fp32={row['wall_speedup_fp32']}x;"
            f"wall_speedup_bf16={row['wall_speedup_bf16']}x;"
            f"flop_reduction={row['flop_reduction_cost_analysis']}x;"
            f"bit_agree={bit_agree:.4f};"
            f"decision_agree={decision_agree:.3f}")
    common.save_json("BENCH_decode", rows)
    return rows


if __name__ == "__main__":
    main()
