"""Fig. 13 (repo-native): content-addressed result cache under a
Zipf repeat-heavy workload.

Real provenance-checking traffic is repeat-heavy — the same viral
image is checked by many users, retried by clients, and mirrored
across feeds.  This figure drives the online server with an open-loop
Poisson arrival process whose request images are drawn Zipf(s=1.1)
from a fixed pool, with a 70/30 interactive/bulk priority mix, and
compares two arms that see the *same* arrival and workload sequence:

* ``nocache`` — SLO-tiered admission only (the fig11 runtime plus
  priority classes);
* ``cache`` — tier-1 exact content-hash (sha256) result cache +
  dedup-in-flight on top (``DetectionConfig.cache_exact``).

The claim: at the measured hit rate (>= 50% at s=1.1) the cache arm's
mean request latency is strictly lower and the interactive class's
p95 is no worse — hits bypass admission, queueing, and execution
entirely, and coalesced duplicates stop multiplying executor load.
Cache hits are bitwise the cold-path result (content-derived fold_in
keys), so the speedup costs nothing in output fidelity.

Writes ``experiments/bench/BENCH_cache.json``: one row per arm plus a
``summary`` with the acceptance booleans.
"""
from __future__ import annotations

import jax

from benchmarks import common
from repro.core.detect import DetectionConfig
from repro.core.extractor import init_extractor
from repro.core.rs.codec import DEFAULT_CODE
from repro.launch.serve import run_online

ZIPF_S = 1.1
POOL = 12
BULK_FRAC = 0.3
# interactive preempts bulk; deadlines generalize fig11's max_wait_ms
CLASSES = {"interactive": 8.0, "bulk": 40.0}


def main(quick: bool = False):
    img = 32 if quick else 64
    tile = 16
    raw = img + 32
    duration = 2.5 if quick else 6.0
    qps = 30.0 if quick else 24.0
    max_batch = 8 if quick else 16
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits,
                            channels=8, depth=2)
    rows = []
    arms = {}
    for arm, cache_on in (("nocache", False), ("cache", True)):
        cfg = DetectionConfig(tile=tile, img_size=img,
                              resize_src=img + img // 8, mode="qrmark",
                              rs_mode="device", rs_threads=4,
                              code=DEFAULT_CODE, cache_exact=cache_on)
        rep = run_online(cfg, params, qps=qps, duration_s=duration,
                         raw_size=raw, group=1, max_batch=max_batch,
                         max_wait_ms=8.0, max_queue=128,
                         classes=CLASSES, bulk_frac=BULK_FRAC,
                         zipf=ZIPF_S, pool=POOL, seed=0, quiet=True)
        rep["arm"] = arm
        rows.append(rep)
        arms[arm] = rep
        cache = rep.get("cache", {})
        common.emit(
            f"fig13/{arm}",
            rep["latency_ms"]["mean"] / 1e3,
            f"p95i={rep['latency_ms_by_class']['interactive']['p95']}ms;"
            f"hit_rate={cache.get('hit_rate', 0.0)};"
            f"rps={rep['throughput_rps']};rej={rep['rejected']}")
    base, cached = arms["nocache"], arms["cache"]
    p95_base = base["latency_ms_by_class"]["interactive"]["p95"]
    p95_cache = cached["latency_ms_by_class"]["interactive"]["p95"]
    hit_rate = cached["cache"]["hit_rate"]
    summary = {
        "zipf_s": ZIPF_S, "pool": POOL, "bulk_frac": BULK_FRAC,
        "hit_rate": hit_rate,
        "mean_ms_nocache": base["latency_ms"]["mean"],
        "mean_ms_cache": cached["latency_ms"]["mean"],
        "interactive_p95_ms_nocache": p95_base,
        "interactive_p95_ms_cache": p95_cache,
        "hit_rate_ge_50pct": hit_rate >= 0.5,
        "mean_strictly_better": (cached["latency_ms"]["mean"]
                                 < base["latency_ms"]["mean"]),
        "interactive_p95_no_worse": p95_cache <= p95_base,
    }
    print(f"# fig13 hit_rate={hit_rate:.3f} "
          f"mean {base['latency_ms']['mean']:.2f}ms -> "
          f"{cached['latency_ms']['mean']:.2f}ms, "
          f"interactive p95 {p95_base:.2f}ms -> {p95_cache:.2f}ms",
          flush=True)
    common.save_json("BENCH_cache", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    main()
