"""Paper Fig. 8: optimization breakdown — cumulative speedup from each
QRMark component over the sequential baseline:

  baseline -> +LB (large batch) -> +T+F (tiling + kernel fusion) ->
  +CPU (RS thread pool + codebook) -> +Allocation (adaptive lanes,
  interleaving, on-device RS).
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.fig6_throughput import IMG, RAW, _pipe, run_stream


def main(quick: bool = False):
    tiles = common.trained_tiles()
    if not tiles:
        print("fig8: no trained extractor available", flush=True)
        return []
    params, tcfg = common.load_extractor(32 if 32 in tiles else tiles[0])
    tile = tcfg.tile
    nb = 2 if quick else 4
    b_small, b_large = (16, 64) if quick else (16, 128)

    stages = []
    # 1. sequential baseline at small batch
    p = _pipe("sequential", "cpu_sync", params, tcfg, interleave=False,
              fused=False, tile=tile)
    base = run_stream(p, b_small, nb); p.close()
    stages.append(("baseline", base))
    # 2. +LB: same pipeline, large batch
    p = _pipe("sequential", "cpu_sync", params, tcfg, interleave=False,
              fused=False, tile=tile)
    stages.append(("+LB", run_stream(p, b_large, nb))); p.close()
    # 3. +T+F: tiling + fused preprocess kernel
    p = _pipe("tiled", "cpu_sync", params, tcfg, interleave=False,
              fused=True, tile=tile)
    stages.append(("+T+F", run_stream(p, b_large, nb))); p.close()
    # 4. +CPU: RS correction thread pool + codebook
    p = _pipe("tiled", "cpu_pool", params, tcfg, interleave=False,
              fused=True, tile=tile)
    stages.append(("+CPU", run_stream(p, b_large, nb))); p.close()
    # 5. +Allocation: full qrmark (lanes, interleave, on-device RS)
    p = _pipe("qrmark", "device", params, tcfg, tile=tile)
    stages.append(("+Allocation", run_stream(p, b_large, nb))); p.close()

    rows = []
    for name, ips in stages:
        rows.append({"config": name, "ips": round(ips, 1),
                     "speedup": round(ips / base, 2)})
        common.emit(f"fig8/{name}", 1.0 / max(ips, 1e-9),
                    f"ips={ips:.1f};speedup={ips / base:.2f}x")
    common.save_json("fig8_breakdown", rows)
    return rows


if __name__ == "__main__":
    main()
