"""Paper Fig. 8: optimization breakdown — cumulative speedup from each
QRMark component over the sequential baseline:

  baseline -> +LB (large batch) -> +T+F (tiling + kernel fusion) ->
  +CPU (RS thread pool + codebook) -> +Allocation (adaptive multi-lane
  execution, interleaving, on-device RS).

Every configuration runs through the stage-graph lane executor; the
final step is the one that actually turns the allocator's stream
vector into concurrent lanes.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.fig6_throughput import IMG, RAW, _pipe, run_stream


def main(quick: bool = False):
    params, tcfg, trained = common.load_or_init_extractor(32)
    if not trained:
        print("fig8: no trained extractor — using an untrained one "
              "(throughput only)", flush=True)
    tile = tcfg.tile
    nb = 2 if quick else 4
    b_small, b_large = (16, 64) if quick else (16, 128)

    stages = []
    # 1. sequential baseline at small batch
    p = _pipe("sequential", "cpu_sync", params, tcfg, interleave=False,
              fused=False, tile=tile)
    base, lm = run_stream(p, b_small, nb, lanes=1); p.close()
    stages.append(("baseline", base, lm))
    # 2. +LB: same pipeline, large batch
    p = _pipe("sequential", "cpu_sync", params, tcfg, interleave=False,
              fused=False, tile=tile)
    ips, lm = run_stream(p, b_large, nb, lanes=1); p.close()
    stages.append(("+LB", ips, lm))
    # 3. +T+F: tiling + fused preprocess kernel
    p = _pipe("tiled", "cpu_sync", params, tcfg, interleave=False,
              fused=True, tile=tile)
    ips, lm = run_stream(p, b_large, nb, lanes=1); p.close()
    stages.append(("+T+F", ips, lm))
    # 4. +CPU: RS correction thread pool + codebook
    p = _pipe("tiled", "cpu_pool", params, tcfg, interleave=False,
              fused=True, tile=tile)
    ips, lm = run_stream(p, b_large, nb, lanes=1); p.close()
    stages.append(("+CPU", ips, lm))
    # 5. +Allocation: full qrmark — multi-lane executor, interleave,
    # on-device RS (lanes=None -> the pipeline's default lane split)
    p = _pipe("qrmark", "device", params, tcfg, tile=tile)
    ips, lm = run_stream(p, b_large, nb, lanes=None); p.close()
    stages.append(("+Allocation", ips, lm))

    rows = []
    for name, ips, lane_map in stages:
        rows.append({"config": name, "ips": round(ips, 1),
                     "lanes": sum(lane_map.values()),
                     "speedup": round(ips / base, 2)})
        common.emit(f"fig8/{name}", 1.0 / max(ips, 1e-9),
                    f"ips={ips:.1f};speedup={ips / base:.2f}x;"
                    f"lanes={sum(lane_map.values())}")
    common.save_json("fig8_breakdown", rows)
    return rows


if __name__ == "__main__":
    main()
