"""Paper §3 motivation: a fixed stream allocation that helps at B=256
hurts at B=16 — the adaptive allocator must choose differently per batch.

Uses the calibrated stage-time model with profiles measured from the real
pipeline stages, evaluating (1,1,16) fixed vs Algorithm-1 allocations."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import allocator


def measured_profiles():
    """Profile preprocess / decode / RS on the real pipeline if a trained
    extractor exists, else use the paper-calibrated defaults."""
    loaded = common.load_extractor(32) or (
        common.load_extractor(16) if common.trained_tiles() else None)
    if loaded is None:
        return [allocator.StageProfile("pre", 2e-5, 2e5, 3e-4),
                allocator.StageProfile("dec", 8e-5, 1e6, 3e-4),
                allocator.StageProfile("rs", 4e-4, 64.0, 1e-4)]
    import jax
    import jax.numpy as jnp
    from repro.core.detect import DetectionConfig, DetectionPipeline
    from repro.core.rs.codec import rs_decode
    from repro.data.pipeline import synth_image
    import time

    params, tcfg = loaded
    cfg = DetectionConfig(tile=tcfg.tile, img_size=128, resize_src=144,
                          mode="qrmark", rs_mode="cpu_sync",
                          code=tcfg.code)
    pipe = DetectionPipeline(cfg, params["dec"])
    raw = jnp.asarray(np.stack([synth_image(i, 160) for i in range(16)]))
    key = jax.random.key(0)
    # profile the actual stage functions (tile-first ingest emits the
    # decode input directly; staged ingest the full preprocessed image)
    pre = allocator.profile_stage(
        lambda b: jax.block_until_ready(pipe._ingest(b, key)), raw,
        name="pre")
    x, keys = pipe._ingest(raw, key)
    dec = allocator.profile_stage(
        lambda b: jax.block_until_ready(
            pipe._decode_x(b, keys[: b.shape[0]])), x,
        name="dec")
    bits = np.asarray((pipe._decode_x(x, keys) > 0).astype(np.int32))
    t0 = time.perf_counter()
    for r in bits:
        rs_decode(cfg.code, r)
    rs_t = (time.perf_counter() - t0) / len(bits)
    return [pre, dec, allocator.StageProfile("rs", rs_t, 64.0, 1e-4)]


# The cap must BIND (as real GPU memory does for full-res image batches)
# for stream augmentation to have waves to parallelise — same regime as
# the paper's H100 profiling.
MEM_CAP = 3.0e7


def model_time(profiles, streams, B, mem_cap=MEM_CAP):
    m = B
    while m > 1 and not allocator.mem_ok(profiles, streams, [m] * 3,
                                         mem_cap):
        m //= 2
    return max(allocator.stage_time(p, s, m, B)
               for p, s in zip(profiles, streams))


def main(quick: bool = False):
    profs = measured_profiles()
    rows = []
    for B in (16, 256):
        t_single = model_time(profs, [1, 1, 1], B)
        t_fixed = model_time(profs, [1, 1, 16], B)
        alloc = allocator.adaptive_allocation(profs, global_batch=B,
                                              stream_budget=18,
                                              mem_cap=MEM_CAP)
        t_adapt = alloc.bottleneck_s
        row = {"batch": B,
               "single_stream_s": round(t_single, 5),
               "fixed_1_1_16_s": round(t_fixed, 5),
               "fixed_speedup": round(t_single / t_fixed, 2),
               "adaptive_streams": alloc.streams,
               "adaptive_s": round(t_adapt, 5),
               "adaptive_speedup": round(t_single / t_adapt, 2)}
        rows.append(row)
        common.emit(f"alloc_adaptivity/B{B}", t_adapt,
                    f"fixed={row['fixed_speedup']}x;"
                    f"adaptive={row['adaptive_speedup']}x;"
                    f"streams={alloc.streams}")
    common.save_json("alloc_adaptivity", rows)
    return rows


if __name__ == "__main__":
    main()
