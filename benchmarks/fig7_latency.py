"""Paper Fig. 7: per-batch end-to-end latency vs batch size (QRMark's
latency grows much slower than the sequential baseline's)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.fig6_throughput import IMG, RAW, TILE, _pipe
from repro.data.pipeline import synth_image

BATCHES = (8, 16, 32, 64, 128)


def batch_latency(pipe, batch, iters=3):
    raw = np.stack([synth_image(i, RAW) for i in range(batch)])
    pipe.detect_batch(raw)  # warm
    t0 = time.perf_counter()
    for _ in range(iters):
        pipe.detect_batch(raw)
    return (time.perf_counter() - t0) / iters


def main(quick: bool = False):
    params, tcfg, trained = common.load_or_init_extractor(TILE)
    if not trained:
        print("fig7: no trained extractor — using an untrained one "
              "(latency only)", flush=True)
    batches = BATCHES[:3] if quick else BATCHES
    rows = []
    for b in batches:
        base = _pipe("sequential", "cpu_sync", params, tcfg,
                     interleave=False, fused=False, tile=tcfg.tile)
        l_base = batch_latency(base, b, iters=2 if quick else 3)
        qr = _pipe("qrmark", "device", params, tcfg, tile=tcfg.tile)
        l_qr = batch_latency(qr, b, iters=2 if quick else 3)
        base.close(); qr.close()
        row = {"batch": b, "baseline_ms": round(l_base * 1e3, 1),
               "qrmark_ms": round(l_qr * 1e3, 1),
               "ratio": round(l_base / l_qr, 2) if l_qr else None}
        rows.append(row)
        common.emit(f"fig7/batch{b}", l_qr,
                    f"qrmark={row['qrmark_ms']}ms;"
                    f"base={row['baseline_ms']}ms;ratio={row['ratio']}")
    common.save_json("fig7_latency", rows)
    return rows


if __name__ == "__main__":
    main()
