"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import pickle
import sys
import time
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]
SRC = str(REPO / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

EXTRACTOR_DIR = REPO / "experiments" / "extractor"
DRYRUN_DIR = REPO / "experiments" / "dryrun"
OUT_DIR = REPO / "experiments" / "bench"


def load_extractor(tile: int):
    """Trained (params, cfg) for a tile size, or None if not trained."""
    p = EXTRACTOR_DIR / f"tile{tile}_params.pkl"
    if not p.exists():
        return None
    with open(p, "rb") as f:
        d = pickle.load(f)
    return d["params"], d["cfg"]


def trained_tiles():
    return sorted(int(p.stem.split("_")[0][4:])
                  for p in EXTRACTOR_DIR.glob("tile*_params.pkl"))


def load_or_init_extractor(tile: int):
    """(params, cfg, trained) — the trained artifact when present, else a
    freshly initialised extractor.  Throughput benchmarks only need the
    compute graph, not a converged model, so a fresh checkout can still
    run fig6/fig7/fig8 end-to-end (accuracy tables DO require training —
    they stay artifact-gated)."""
    for t in (tile, *trained_tiles()):
        loaded = load_extractor(t)
        if loaded is not None:
            return loaded[0], loaded[1], True
    import jax
    from repro.core.extractor import init_encoder, init_extractor
    from repro.core.train_extractor import ExtractorTrainConfig
    cfg = ExtractorTrainConfig(tile=tile)
    n_bits = cfg.code.codeword_bits
    params = {"dec": init_extractor(jax.random.key(0), n_bits=n_bits,
                                    tile=tile),
              "enc": init_encoder(jax.random.key(1), n_bits=n_bits,
                                  tile=tile)}
    return params, cfg, False


def cost_analysis(fn, *args):
    """(flops, bytes accessed) of a jitted fn per XLA ``cost_analysis``
    (papers over the list-vs-dict return across jax versions)."""
    c = fn.lower(*args).compile().cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return (float(c.get("flops", 0.0)),
            float(c.get("bytes accessed", 0.0)))


def timeit(fn, *args, iters=3, warmup=1):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def emit(name: str, seconds_per_call: float, derived: str):
    """The `name,us_per_call,derived` CSV contract of benchmarks.run."""
    print(f"{name},{seconds_per_call * 1e6:.1f},{derived}", flush=True)


def save_json(name: str, obj):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    (OUT_DIR / f"{name}.json").write_text(json.dumps(obj, indent=1,
                                                     default=str))


def ber_model():
    """Measured bit-error-rate vs bits-per-pixel from the trained
    extractors (used to extrapolate untrained cells; documented in
    EXPERIMENTS.md)."""
    pts = []
    for t in trained_tiles():
        rep = EXTRACTOR_DIR / f"tile{t}_report.json"
        if not rep.exists():
            continue
        r = json.loads(rep.read_text())
        ba = r["eval"].get("none", {}).get("bit_acc")
        if ba is None:
            continue
        n_bits = r["config"]["code"][0] * r["config"]["code"][1]
        pts.append((n_bits / (t * t), 1.0 - ba))
    return sorted(pts)
