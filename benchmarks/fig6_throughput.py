"""Paper Fig. 6: end-to-end detection throughput vs batch size, QRMark
pipeline vs the sequential Stable-Signature-style baseline.

This container has one CPU device, so absolute numbers are CPU-bound;
the claim being reproduced is the RELATIVE speedup curve (the paper's
2.43x average comes from tiling + fused preprocess + async RS + lane
scheduling, all active here)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.data.pipeline import synth_image

BATCHES = (8, 16, 32, 64, 128)
IMG = 128
RAW = 160
TILE = 32


def _pipe(mode, rs_mode, params, cfg_train, interleave=True, fused=True,
          tile=TILE):
    cfg = DetectionConfig(tile=tile, img_size=IMG, resize_src=RAW - 16,
                          mode=mode, rs_mode=rs_mode, rs_threads=8,
                          interleave=interleave, fused_preprocess=fused,
                          code=cfg_train.code)
    return DetectionPipeline(cfg, params["dec"])


def run_stream(pipe, batch, n_batches):
    data = [np.stack([synth_image(k * batch + i, RAW)
                      for i in range(batch)]) for k in range(n_batches)]
    r = pipe.run_stream(data)
    return r["throughput_ips"]


def main(quick: bool = False):
    loaded = common.load_extractor(TILE) or common.load_extractor(16)
    if loaded is None:
        print("fig6: no trained extractor available", flush=True)
        return []
    params, tcfg = loaded
    tile = tcfg.tile
    n_batches = 2 if quick else 4
    batches = BATCHES[:3] if quick else BATCHES
    rows = []
    for b in batches:
        base = _pipe("sequential", "cpu_sync", params, tcfg,
                     interleave=False, fused=False, tile=tile)
        t_base = run_stream(base, b, n_batches)
        qr = _pipe("qrmark", "device", params, tcfg, tile=tile)
        t_qr = run_stream(qr, b, n_batches)
        qr.close(); base.close()
        row = {"batch": b, "baseline_ips": round(t_base, 1),
               "qrmark_ips": round(t_qr, 1),
               "speedup": round(t_qr / t_base, 2) if t_base else None}
        rows.append(row)
        common.emit(f"fig6/batch{b}", 1.0 / max(t_qr, 1e-9),
                    f"qrmark={t_qr:.1f}ips;base={t_base:.1f}ips;"
                    f"speedup={row['speedup']}")
    common.save_json("fig6_throughput", rows)
    return rows


if __name__ == "__main__":
    main()
