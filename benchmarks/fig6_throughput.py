"""Paper Fig. 6: end-to-end detection throughput vs batch size —
sequential Stable-Signature-style baseline vs naive tiling vs the full
QRMark pipeline, all executed through the multi-lane stage-graph
executor (``repro.core.lanes``), with the per-mode lane assignment
reported alongside throughput.

This container has one CPU device, so absolute numbers are CPU-bound;
the claim being reproduced is the RELATIVE speedup curve (the paper's
2.43x average comes from tiling + fused preprocess + async RS + lane
scheduling, all active here)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.data.pipeline import synth_image

BATCHES = (8, 16, 32, 64, 128)
IMG = 128
RAW = 160
TILE = 32

# (mode, rs_mode, interleave, fused, lanes arg for run_stream)
MODES = (
    ("sequential", "cpu_sync", False, False, 1),
    ("tiled", "cpu_sync", False, True, 1),
    ("qrmark", "device", True, True, None),   # None -> default lane split
)


def _pipe(mode, rs_mode, params, cfg_train, interleave=True, fused=True,
          tile=TILE):
    cfg = DetectionConfig(tile=tile, img_size=IMG, resize_src=RAW - 16,
                          mode=mode, rs_mode=rs_mode, rs_threads=8,
                          interleave=interleave, fused_preprocess=fused,
                          code=cfg_train.code)
    return DetectionPipeline(cfg, params["dec"])


def run_stream(pipe, batch, n_batches, lanes=None):
    data = [np.stack([synth_image(k * batch + i, RAW)
                      for i in range(batch)]) for k in range(n_batches)]
    r = pipe.run_stream(data, lanes=lanes)
    return r["throughput_ips"], r.get("lanes", {})


def main(quick: bool = False):
    params, tcfg, trained = common.load_or_init_extractor(TILE)
    if not trained:
        print("fig6: no trained extractor — using an untrained one "
              "(throughput only)", flush=True)
    tile = tcfg.tile
    n_batches = 2 if quick else 4
    batches = BATCHES[:3] if quick else BATCHES
    rows = []
    for b in batches:
        ips = {}
        for mode, rs_mode, inter, fused, lanes in MODES:
            p = _pipe(mode, rs_mode, params, tcfg, interleave=inter,
                      fused=fused, tile=tile)
            t, lane_map = run_stream(p, b, n_batches, lanes=lanes)
            p.close()
            ips[mode] = t
            rows.append({"batch": b, "mode": mode,
                         "lanes": sum(lane_map.values()),
                         "lane_map": lane_map, "ips": round(t, 1),
                         "speedup": None})
        for row in rows[-len(MODES):]:
            row["speedup"] = (round(row["ips"] / ips["sequential"], 2)
                              if ips["sequential"] else None)
        common.emit(
            f"fig6/batch{b}", 1.0 / max(ips["qrmark"], 1e-9),
            f"qrmark={ips['qrmark']:.1f}ips;tiled={ips['tiled']:.1f}ips;"
            f"base={ips['sequential']:.1f}ips;"
            f"speedup={ips['qrmark'] / max(ips['sequential'], 1e-9):.2f}")
    common.save_json("fig6_throughput", rows)
    return rows


if __name__ == "__main__":
    main()
