"""Paper Table 4: validation accuracy of each tiling strategy across tile
sizes (the Random-Grid-wins ablation)."""
from __future__ import annotations

from benchmarks import common
from repro.core.tiling import STRATEGIES
from repro.core.train_extractor import evaluate


def main(quick: bool = False):
    n_img = 32 if quick else 96
    rows = {s: {"strategy": s} for s in STRATEGIES}
    for tile in common.trained_tiles():
        params, cfg = common.load_extractor(tile)
        for strat in STRATEGIES:
            ev = evaluate(params, cfg, n_images=n_img, attacks=("none",),
                          strategy=strat)
            rows[strat][f"tile{tile}"] = round(ev["none"]["bit_acc"], 3)
    out = list(rows.values())
    for r in out:
        common.emit(f"table4/{r['strategy']}", 0.0,
                    ";".join(f"{k}={v}" for k, v in r.items()
                             if k != "strategy"))
    common.save_json("table4_tile_sizes", out)
    return out


if __name__ == "__main__":
    main()
