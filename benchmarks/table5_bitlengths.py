"""Paper Table 5: bit- and word-level accuracy vs payload length (40..96
bits) at tile 64 — the word-accuracy collapse past 48 bits.

Channel quality (per-bit error rate) is taken from the measured BER of
the trained extractors as a function of embedding density
(bits-per-pixel), then the REAL RS codec (encode -> binomial bit flips ->
Berlekamp-Welch decode) is run per payload length.  This reproduces the
collapse mechanism — redundancy t = (n-k)/2 shrinking while the error
rate grows — with the actual decoder rather than an analytic formula.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.rs.codec import RSCode, rs_decode, rs_encode

BITS = (40, 48, 56, 64, 72, 80, 96)
TILE = 64


def code_for(bits: int) -> RSCode:
    """GF(16) systematic code with the paper's default 3 parity symbols
    (t=1) while the length bound allows; longer payloads switch to a
    short GF(256) code with the same t=1 redundancy (paper App. A:
    'k is selected dynamically' for larger payloads)."""
    k = -(-bits // 4)
    if k + 3 <= 15:
        return RSCode(m=4, n=k + 3, k=k)
    k8 = -(-bits // 8)
    return RSCode(m=8, n=k8 + 2, k=k8)


def _ber_at_density(density: float, pts) -> float:
    """Interpolate measured (density, ber) points; clamp at the ends."""
    if not pts:
        # fallback: calibrated logistic in density (documented)
        return float(1 / (1 + np.exp(-(density * 40 - 3.2))) * 0.45)
    xs = np.array([p[0] for p in pts])
    ys = np.array([max(p[1], 1e-4) for p in pts])
    return float(np.interp(density, xs, ys))


# the paper's own Table-5 bit-accuracy row (their extractor's channel
# quality per payload length at tile 64) — used to validate that the
# word-accuracy collapse emerges from OUR RS decoder given their channel
PAPER_BITACC = {40: 0.99, 48: 0.99, 56: 0.98, 64: 0.91, 72: 0.89,
                80: 0.84, 96: 0.77}


def _mc(code, ber, trials, rng):
    bit_ok = word_ok = 0
    for _ in range(trials):
        msg = rng.integers(0, 2, code.message_bits)
        cw = rs_encode(code, msg)
        flips = rng.random(code.codeword_bits) < ber
        res = rs_decode(code, cw ^ flips)
        bit_ok += (res.message_bits == msg).mean()
        word_ok += res.ok and np.array_equal(res.message_bits, msg)
    return bit_ok / trials, word_ok / trials


def main(quick: bool = False):
    pts = common.ber_model()
    trials = 100 if quick else 400
    rng = np.random.default_rng(0)
    rows = []
    for bits in BITS:
        code = code_for(bits)
        density = code.codeword_bits / (TILE * TILE)
        ber = _ber_at_density(density, pts)
        bit_acc, word_acc = _mc(code, ber, trials, rng)
        # same codec on the PAPER's per-length channel quality
        p_bit, p_word = _mc(code, 1.0 - PAPER_BITACC[bits], trials, rng)
        row = {"bits": bits, "code": f"({code.n},{code.k})xGF(2^{code.m})",
               "ours_ber": round(ber, 4),
               "ours_bit_acc": round(bit_acc, 3),
               "ours_word_acc": round(word_acc, 3),
               "paper_channel_bit_acc": round(p_bit, 3),
               "paper_channel_word_acc": round(p_word, 3)}
        rows.append(row)
        common.emit(f"table5/bits{bits}", 0.0,
                    f"ours_word={row['ours_word_acc']}(ber={ber:.3f});"
                    f"paper_channel_word={row['paper_channel_word_acc']}"
                    f"(ber={1 - PAPER_BITACC[bits]:.2f})")
    common.save_json("table5_bitlengths", rows)
    return rows


if __name__ == "__main__":
    main()
