"""Fig. 11 (repo-native): online request-level serving — offered load
vs latency percentiles.

The offline figures (fig6-8) measure batch-stream throughput; this one
measures the regime a provenance-checking service actually runs in:
single-image requests arriving as an open-loop Poisson process, the
dynamic micro-batcher coalescing them under a deadline, and the
persistent service-mode lane executor detecting them.  For each mode
(sequential / tiled / qrmark) the offered load is swept and p50/p95/p99
request latency, completed throughput, rejection count, and batch
occupancy are recorded.

The claim: at an equal latency budget the qrmark stage graph (tile-first
fused ingest + fused tile decode + device RS + multi-lane execution)
sustains a strictly higher offered load than the sequential baseline —
the online restatement of the paper's 2.43x offline speedup.

Writes ``experiments/bench/BENCH_online.json``: one row per
(mode, qps) plus a ``sustained_qps`` summary per mode at the shared
latency budget.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core.detect import DetectionConfig
from repro.core.extractor import init_extractor
from repro.core.rs.codec import DEFAULT_CODE
from repro.data.pipeline import synth_image
from repro.launch.serve import open_loop_load
from repro.serving import BatcherConfig, DetectionServer

# (mode, rs_mode, fused_preprocess) — mirrors fig6's mode table
MODES = (
    ("sequential", "cpu_sync", False),
    ("tiled", "cpu_sync", True),
    ("qrmark", "device", True),
)
QPS_SWEEP = (4.0, 8.0, 16.0, 32.0, 64.0)
QPS_SWEEP_QUICK = (4.0, 16.0)
# shared p95 budget for the sustained-load summary: comfortably above
# qrmark's ~15ms tail and comfortably below the 50-200ms sequential /
# tiled tails on the CI smoke box, so the per-mode separation is robust
# to run-to-run noise
LATENCY_BUDGET_MS = 30.0


def _server(mode: str, rs_mode: str, fused: bool, params, *,
            img: int, tile: int, max_batch: int,
            max_wait_ms: float) -> DetectionServer:
    cfg = DetectionConfig(tile=tile, img_size=img,
                          resize_src=img + img // 8, mode=mode,
                          rs_mode=rs_mode, rs_threads=4,
                          fused_preprocess=fused, code=DEFAULT_CODE)
    return DetectionServer(
        cfg, params,
        batcher=BatcherConfig(max_batch=max_batch,
                              max_wait_ms=max_wait_ms, max_queue=128))


def drive(srv: DetectionServer, *, qps: float, duration_s: float,
          raw: int, seed: int = 0) -> dict:
    srv.metrics.reset()
    load = open_loop_load(
        srv, qps=qps, duration_s=duration_s, seed=seed,
        make_images=lambda k: synth_image(1000 + k, raw)[None])
    srv.drain(timeout=120.0)
    stats = srv.stats()
    lat = stats.get("request_latency_s", {})
    return {
        "offered": load["offered"], "rejected": load["rejected"],
        "completed": int(stats["counters"].get("requests_completed", 0)),
        "throughput_rps": round(stats["throughput_rps"], 2),
        "p50_ms": round(lat.get("p50", float("nan")) * 1e3, 2),
        "p95_ms": round(lat.get("p95", float("nan")) * 1e3, 2),
        "p99_ms": round(lat.get("p99", float("nan")) * 1e3, 2),
        "occupancy": round(
            stats.get("batch_occupancy", {}).get("mean", float("nan")),
            3),
        "straggler_retries": stats["straggler_retries"],
    }


def main(quick: bool = False):
    img = 32 if quick else 64
    tile = 16
    raw = img + 32
    duration = 2.5 if quick else 6.0
    sweep = QPS_SWEEP_QUICK if quick else QPS_SWEEP
    max_batch = 8 if quick else 16
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits,
                            channels=8, depth=2)
    rows = []
    sustained = {}
    for mode, rs_mode, fused in MODES:
        srv = _server(mode, rs_mode, fused, params, img=img, tile=tile,
                      max_batch=max_batch, max_wait_ms=8.0)
        srv.warmup(synth_image(0, raw))
        srv.start()
        best = 0.0
        try:
            for qps in sweep:
                r = drive(srv, qps=qps, duration_s=duration, raw=raw)
                r.update({"mode": mode, "qps_offered": qps,
                          "lanes": srv.lane_counts()})
                rows.append(r)
                if (r["rejected"] == 0 and np.isfinite(r["p95_ms"])
                        and r["p95_ms"] <= LATENCY_BUDGET_MS):
                    best = max(best, qps)
                common.emit(
                    f"fig11/{mode}_qps{qps:g}",
                    (r["p50_ms"] / 1e3 if np.isfinite(r["p50_ms"])
                     else 0.0),
                    f"p95={r['p95_ms']}ms;p99={r['p99_ms']}ms;"
                    f"rps={r['throughput_rps']};rej={r['rejected']};"
                    f"occ={r['occupancy']}")
        finally:
            srv.close()
        sustained[mode] = best
    summary = {
        "latency_budget_ms": LATENCY_BUDGET_MS,
        "sustained_qps": sustained,
        "qrmark_vs_sequential": (
            sustained["qrmark"] / sustained["sequential"]
            if sustained.get("sequential") else None),
    }
    print(f"# fig11 sustained qps @ p95<={LATENCY_BUDGET_MS:g}ms: "
          f"{sustained}", flush=True)
    common.save_json("BENCH_online", {"rows": rows, "summary": summary})
    return rows


if __name__ == "__main__":
    main()
