"""Fig. 14 (repo-native): multi-replica fleet scaling and chaos.

One DetectionServer saturates one device; scaling a provenance service
means a fleet of replicas behind a router.  This figure drives the
:class:`~repro.serving.FleetRouter` (rendezvous content-digest routing,
spill-over on backpressure, crash re-execution) with the fig11 open-loop
Poisson generator and answers two questions:

* **scaling** — aggregate sustained qps vs replica count, where
  "sustained" is the highest offered qps whose p95 stays inside the
  30 ms interactive budget (fig11's ``LATENCY_BUDGET_MS``) with zero
  admission rejections.  Sustained qps must be monotonically
  non-decreasing 1 -> 2 -> 4 replicas;
* **chaos** — the kill-one-replica arm: a :class:`FaultPlan` crashes a
  replica mid-run with requests in flight.  Every offered request must
  still complete (``reroutes > 0``, ``unresolved == 0``, zero failed)
  via sibling re-execution.

The fleet runs in a **subprocess** with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
``tests/sharded_check.py`` CI-scale simulation: one forced CPU device
per replica, pinned via ``jax.default_device``) — the flag only takes
effect before jax initialises, and the parent harness has usually
already imported jax.  The child writes
``experiments/bench/BENCH_fleet.json``; the parent re-reads it and
emits the CSV rows.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from benchmarks import common

LATENCY_BUDGET_MS = 30.0  # fig11's interactive budget, reused verbatim
FORCED_DEVICES = 4


def _sustained(rows, n_replicas):
    """Max offered qps with p95 <= budget and rejected == 0, else 0."""
    ok = [r["qps_offered"] for r in rows
          if r["replicas"] == n_replicas and r["rejected"] == 0
          and r["latency_ms"]["p95"] <= LATENCY_BUDGET_MS]
    return max(ok) if ok else 0.0


def child_main(quick: bool = False):
    """Runs inside the forced-4-device subprocess."""
    import jax
    from repro.core.detect import DetectionConfig
    from repro.core.extractor import init_extractor
    from repro.core.rs.codec import DEFAULT_CODE
    from repro.launch.serve import run_fleet
    from repro.serving import FaultPlan

    img, tile = 32, 16           # smoke config: scaling shape, not size
    raw = img + 32
    counts = (1, 2) if quick else (1, 2, 4)
    qps_points = (8.0, 16.0) if quick else (8.0, 16.0, 24.0)
    duration = 1.5 if quick else 3.0
    cfg = DetectionConfig(tile=tile, img_size=img, resize_src=img + 8,
                          mode="qrmark", rs_mode="device",
                          code=DEFAULT_CODE)
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits,
                            channels=8, depth=2)

    rows = []
    for n in counts:
        for qps in qps_points:
            rep = run_fleet(cfg, params, replicas=n, qps=qps,
                            duration_s=duration, raw_size=raw,
                            max_batch=8, max_wait_ms=5.0,
                            max_queue=256, seed=0, quiet=True)
            rows.append(rep)
            print(f"# fig14 r{n}@{qps}qps: p95="
                  f"{rep['latency_ms']['p95']}ms rej={rep['rejected']} "
                  f"unresolved={rep['unresolved']}", flush=True)

    # chaos arm: crash one of two replicas after it admits its 3rd
    # request — in-flight work must re-execute on the sibling
    chaos = run_fleet(cfg, params, replicas=2, qps=qps_points[-1],
                      duration_s=duration, raw_size=raw,
                      max_batch=8, max_wait_ms=50.0, max_queue=256,
                      seed=1, quiet=True,
                      fault_plans={"r0": FaultPlan(crash_after_admit=2)})
    admitted = chaos["offered"] - chaos["rejected"]
    chaos_summary = {
        "scenario": "kill_replica_mid_run",
        "offered": chaos["offered"],
        "rejected": chaos["rejected"],
        "admitted": admitted,
        "completed": chaos["completed"],
        "unresolved": chaos["unresolved"],
        "failed": chaos["failed"],
        "all_admitted_completed": (chaos["completed"] == admitted
                                   and chaos["unresolved"] == 0
                                   and chaos["failed"] == 0),
        "kill_observed": chaos["unhealthy"] >= 1,
        "p95_ms": chaos["latency_ms"]["p95"],
        "spillovers": chaos["spillovers"],
        "reroutes": chaos["reroutes"],
        "unhealthy": chaos["unhealthy"],
        "straggler_retries": chaos["straggler_retries"],
        "faults_fired": chaos["faults_injected"] + chaos["unhealthy"],
    }

    sustained = {str(n): _sustained(rows, n) for n in counts}
    vals = [sustained[str(n)] for n in counts]
    summary = {
        "latency_budget_ms": LATENCY_BUDGET_MS,
        "sustained_qps": sustained,
        "monotonic_1_to_4": all(b >= a for a, b in zip(vals, vals[1:])),
        "chaos": chaos_summary,
        "environment": {
            "cpu_count": os.cpu_count(),
            "jax_device_count": jax.device_count(),
            "xla_flags": os.environ.get("XLA_FLAGS", ""),
        },
    }
    common.save_json("BENCH_fleet", {"rows": rows, "summary": summary})
    print(f"# fig14 sustained={sustained} "
          f"monotonic={summary['monotonic_1_to_4']} "
          f"chaos reroutes={chaos['reroutes']} "
          f"all_admitted_completed="
          f"{chaos_summary['all_admitted_completed']}", flush=True)


def main(quick: bool = False):
    repo = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{FORCED_DEVICES}").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(repo / "src"), str(repo),
         *filter(None, [env.get("PYTHONPATH")])])
    cmd = [sys.executable, str(Path(__file__).resolve()), "--child"]
    if quick:
        cmd.append("--quick")
    subprocess.run(cmd, env=env, cwd=str(repo), check=True)

    data = json.loads(
        (common.OUT_DIR / "BENCH_fleet.json").read_text())
    for r in data["rows"]:
        common.emit(
            f"fig14/r{r['replicas']}@{r['qps_offered']:g}qps",
            r["latency_ms"]["p95"] / 1e3,
            f"rps={r['throughput_rps']};rej={r['rejected']};"
            f"unresolved={r['unresolved']};spill={r['spillovers']};"
            f"reroute={r['reroutes']}")
    s = data["summary"]
    c = s["chaos"]
    common.emit("fig14/chaos", c["p95_ms"] / 1e3,
                f"reroutes={c['reroutes']};unhealthy={c['unhealthy']};"
                f"all_admitted_completed={c['all_admitted_completed']}")
    assert s["monotonic_1_to_4"], \
        f"sustained qps not monotonic in replica count: " \
        f"{s['sustained_qps']}"
    assert c["all_admitted_completed"], \
        "chaos arm dropped admitted requests"
    assert c["kill_observed"], "chaos arm never killed a replica"
    assert c["reroutes"] > 0, \
        "chaos arm completed without re-executing in-flight work"
    return data["rows"]


if __name__ == "__main__":
    if "--child" in sys.argv:
        child_main(quick="--quick" in sys.argv)
    else:
        main(quick="--quick" in sys.argv)
