"""Sharded checkpointing with async save, atomic commit, and elastic
restore (resharding onto a different mesh).

Layout (one directory per step):

    ckpt_dir/step_000010/
        manifest.json      # tree structure, shapes, dtypes, shard map
        shard_000.npz      # flat arrays owned by logical shard 0
        ...
        COMMIT             # written last: a checkpoint without it is torn

Fault-tolerance contract:
* ``save`` is atomic: writes to a temp dir, fsyncs, renames, then writes
  COMMIT — a crash mid-save never corrupts the latest valid checkpoint.
* ``AsyncCheckpointer`` snapshots device arrays to host, then persists on
  a background thread so the train loop never blocks on disk.
* ``restore`` takes the *current* mesh/shardings: arrays are re-laid-out
  on load, so a job restarted with a different pod count (elastic
  rescale) restores transparently.
* ``latest_step``/``gc`` implement retention.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

# dtypes that numpy cannot round-trip through .npz natively
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _to_storable(arr: np.ndarray):
    for name, (dt, view_dt) in _EXOTIC.items():
        if arr.dtype == dt:
            return arr.view(view_dt), name
    return arr, str(arr.dtype)


def _from_storable(arr: np.ndarray, dtype_name: str):
    if dtype_name in _EXOTIC:
        dt, view_dt = _EXOTIC[dtype_name]
        return arr.view(dt)
    return arr


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save(ckpt_dir, step: int, tree, *, shard_mb: int = 512,
         keep: Optional[int] = None) -> Path:
    """Synchronous atomic save.  Returns the final checkpoint path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    names, leaves, _ = _flatten_with_names(tree)
    host = [np.asarray(l) for l in leaves]

    manifest: Dict[str, Any] = {"step": step, "entries": [], "shards": 0,
                                "time": time.time()}
    shard_bytes = shard_mb * 1024 * 1024
    cur: Dict[str, np.ndarray] = {}
    cur_sz = 0
    shard_idx = 0

    def flush():
        nonlocal cur, cur_sz, shard_idx
        if not cur:
            return
        np.savez(tmp / f"shard_{shard_idx:03d}.npz", **cur)
        shard_idx += 1
        cur, cur_sz = {}, 0

    for i, (name, arr) in enumerate(zip(names, host)):
        key = f"a{i:05d}"
        arr, dtype_name = _to_storable(arr)
        manifest["entries"].append(
            {"name": name, "key": key, "shard": shard_idx,
             "shape": list(arr.shape), "dtype": dtype_name})
        cur[key] = arr
        cur_sz += arr.nbytes
        if cur_sz >= shard_bytes:
            flush()
    flush()
    manifest["shards"] = shard_idx
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    (final / "COMMIT").write_text(str(time.time()))
    if keep is not None:
        gc(ckpt_dir, keep=keep)
    return final


def valid_steps(ckpt_dir) -> List[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    steps = []
    for p in ckpt_dir.glob("step_*"):
        if (p / "COMMIT").exists() and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir) -> Optional[int]:
    s = valid_steps(ckpt_dir)
    return s[-1] if s else None


def gc(ckpt_dir, keep: int = 3):
    steps = valid_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(Path(ckpt_dir) / f"step_{s:08d}", ignore_errors=True)


def restore(ckpt_dir, step: int, target_tree, *, shardings=None):
    """Restore into the structure of ``target_tree`` (abstract or concrete).

    ``shardings``: optional pytree of NamedSharding for the *current* mesh
    — arrays are placed (and re-laid-out) accordingly, which is what makes
    restarting on a different mesh (elastic rescale) work.
    """
    path = Path(ckpt_dir) / f"step_{step:08d}"
    if not (path / "COMMIT").exists():
        raise FileNotFoundError(f"checkpoint {path} is torn or missing")
    manifest = json.loads((path / "manifest.json").read_text())
    by_shard: Dict[int, List[dict]] = {}
    for e in manifest["entries"]:
        by_shard.setdefault(e["shard"], []).append(e)
    arrays: Dict[str, np.ndarray] = {}
    for sidx, entries in by_shard.items():
        with np.load(path / f"shard_{sidx:03d}.npz") as z:
            for e in entries:
                arrays[e["name"]] = _from_storable(z[e["key"]], e["dtype"])

    names, leaves, treedef = _flatten_with_names(target_tree)
    out = []
    flat_sh = (jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(
            x, jax.sharding.Sharding)) if shardings is not None else
        [None] * len(leaves))
    for name, leaf, sh in zip(names, leaves, flat_sh):
        if name not in arrays:
            raise KeyError(f"checkpoint missing entry {name}")
        arr = arrays[name]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{name}: checkpoint shape {arr.shape} != {want_shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree.unflatten(treedef, out)


class AsyncCheckpointer:
    """Non-blocking saves: snapshot to host, persist on a worker thread."""

    def __init__(self, ckpt_dir, *, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, tree):
        self.wait()  # one in-flight save at a time
        host = jax.tree.map(lambda l: np.asarray(l), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host, keep=self.keep)
            except BaseException as e:
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
