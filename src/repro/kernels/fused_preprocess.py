"""Pallas TPU kernel: fused Resize -> CenterCrop -> Normalize.

QRMark Appendix B.1 fuses the fragmented preprocess ops into one Triton
kernel to kill launch overhead and intermediate HBM round-trips.  The TPU
adaptation changes the *algorithm*, not just the API: bilinear resampling
is a gather on GPU, but gathers are slow on the TPU vector unit — instead
the (static) resize+crop composition is expressed as two small
interpolation MATRICES so the whole transform runs on the MXU:

    out[c] = scale_c * (Ry @ img[:, :, c] @ Rx) + bias_c

Ry (crop, H) and Rx (W, crop) each carry <= 2 nonzeros/row (bilinear
weights with half-pixel centers and edge clamp); normalisation folds into
a per-channel affine (scale = 1/(255*std), bias = -mean/std).  One grid
step processes one image: uint8 (H, W, 3) in VMEM (~190KB at 256^2),
f32 out (crop, crop, 3) (~780KB at 256^2) — comfortably within the
~16 MB VMEM budget, MXU-aligned when crop is a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.transforms import IMAGENET_MEAN, IMAGENET_STD
from repro.kernels.ref import resize_matrix


def interp_affine(img, ry, rx, scale, bias):
    """The shared kernel math: per-channel Ry @ img @ Rx + affine
    normalise.  Both the staged and the tile-first kernels
    (``fused_tile_preprocess.py``) call this — one body, so the
    bit-identity contract between the two paths can't silently drift.

    img (H, W, 3) f32; ry (rows, H); rx (W, cols) -> (rows, cols, 3).
    """
    outs = []
    for c in range(3):  # channels unrolled: 2 MXU matmuls per channel
        t = jnp.dot(ry, img[:, :, c], preferred_element_type=jnp.float32)
        t = jnp.dot(t, rx, preferred_element_type=jnp.float32)
        outs.append(t * scale[c] + bias[c])
    return jnp.stack(outs, axis=-1)


def interp_matrices(H: int, W: int, *, resize: int, crop: int):
    """The (crop, H) row / (W, crop) column interpolation matrices of
    the resize+centercrop composition (host constants)."""
    off = (resize - crop) // 2
    ry = jnp.asarray(resize_matrix(H, resize, off, crop))          # (crop,H)
    rx = jnp.asarray(resize_matrix(W, resize, off, crop).T)        # (W,crop)
    return ry, rx


def _kernel(img_ref, ry_ref, rx_ref, scale_ref, bias_ref, out_ref):
    img = img_ref[0].astype(jnp.float32)          # (H, W, 3)
    out_ref[0] = interp_affine(img, ry_ref[...], rx_ref[...],
                               scale_ref[...], bias_ref[...])


def fused_preprocess(raw, *, resize: int = 256, crop: int = 256,
                     mean=None, std=None, interpret: bool = True):
    """uint8 (b, H, W, 3) -> normalized f32 (b, crop, crop, 3).

    interpret=True executes the kernel body on CPU (this container);
    interpret=False is the TPU target.  Not jitted here: mean/std and the
    interpolation matrices are host constants; callers jit around it.
    """
    mean = np.asarray(IMAGENET_MEAN if mean is None else mean, np.float32)
    std = np.asarray(IMAGENET_STD if std is None else std, np.float32)
    b, H, W, C = raw.shape
    assert C == 3
    ry, rx = interp_matrices(H, W, resize=resize, crop=crop)
    scale = jnp.asarray(1.0 / (255.0 * std))
    bias = jnp.asarray(-mean / std)

    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, H, W, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((crop, H), lambda i: (0, 0)),
            pl.BlockSpec((W, crop), lambda i: (0, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, crop, crop, 3), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, crop, crop, 3), jnp.float32),
        interpret=interpret,
    )(raw, ry, rx, scale, bias)
