"""Pallas TPU kernel: the fused extractor decode stage.

After PR 2's tile-first ingest, decode — ``extractor_forward``'s 7-block
conv stack, GAP + head, and the spread-spectrum correlation bank — is
the last hot-path stage still running as an unfused XLA graph at full
precision: every conv block round-trips its (l, l, C) activations
through HBM, and QRMark §5.2 identifies exactly this stage as the
GPU-intensive bottleneck that gets extra streams.  Two kernels share
one math contract:

``fused_extractor`` (the *flat* schedule) runs the whole forward in one
``pallas_call`` with grid=(b,), one image per step, by calling the
shared ``extractor_forward_packed`` body verbatim inside the step.

``fused_extractor_blocked`` (the *blocked* schedule, this PR) re-blocks
that step for throughput while keeping the accumulation order — and
therefore fp32 bitwise output — exactly the same:

* grid=(b // batch_block,): each step owns a (bb, l, l, 3) image block;
* a padded-activation VMEM scratch (bb, l+2, l+2, C) holds every
  inter-layer activation with its halo in place, so layers 1..D read
  their nine tap-shifted views as scratch slices instead of re-running
  a ``jnp.pad`` copy per layer (the flat kernel pays that copy D+1
  times per image);
* a (bb*l*l, C) accumulator scratch collects the conv output one
  channel tile at a time: the weight's output columns are visited in
  [j0, j0+ct) slices, nine N-restricted tap dots per slice.  N-slicing
  a dot never reorders its K-accumulation, so any channel_tile is
  bit-identical to the full-width dot (verified property; contrast
  K-splitting, which is not).  A *cross-step* channel axis is
  impossible here — channel_norm couples all C channels of a layer and
  layer i+1 reads all of layer i — so the tile is an in-body loop that
  bounds the live weight slice, not a grid dimension;
* the bias + channel-norm + ReLU epilogue runs directly on the (M, C)
  GEMM layout ("flat-norm") and the result lands in the scratch
  interior; channel_norm reduces over the channel axis only, so
  skipping the (bb, l, l, C) round-trip is bitwise free and removes
  two reshape copies per layer;
* GAP + head + correlation ride in the same step, written straight to
  the (b, n_bits) logits output.

The precision ladder is carried by the packed params, not the kernel:
fp32 packs are bit-identical to the unfused path on either schedule
(oracle parity by construction), bf16 packs run bf16-input MXU dots
with fp32 accumulation, and int8 packs (``pack_params(..., "int8")``)
run per-channel-scaled int8 weight x dynamically per-row-quantized
activation dots with int32 accumulation and fp32 dequantize — all three
share the per-tap ``tap_dot`` primitive, so RS error correction sees
the same decode semantics at every rung.  (One caveat: int8 is bitwise
schedule-independent only at full channel width — with channel_tile <
C the dequant multiply-add chain may fuse differently per tile width,
leaving ulp-level float noise that the decision layer never sees;
fp32/bf16 are bitwise at every tile.)

Bit-identity depends on every op in the shared body being batch-stable
(see ``extractor_forward_packed``).  interpret=True executes on CPU
(this container); interpret=False is the TPU target, where
``double_buffer`` requests parallel grid-dimension semantics so
consecutive image blocks pipeline their HBM fetches.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch/compiler params; present in this JAX, guarded anyway
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - non-TPU builds
    pltpu = None

from repro.core.extractor import (channel_norm,
                                  extractor_forward_packed_embed, tap_dot)


def _full_spec(shape):
    """BlockSpec broadcasting one whole (weight) array to every step."""
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def fused_extractor(tiles, packed, *, interpret: bool = True,
                    with_embed: bool = False):
    """tiles (b, l, l, 3) f32 + packed extractor params -> (b, n_bits)
    f32 logits, flat schedule (grid=(b,), one image per step).

    ``packed`` is ``extractor.pack_params(params, dtype)`` — built once
    per pipeline, reused across every batch; its leaf dtypes select the
    fp32 / bf16 / int8 compute path.  Not jitted here: callers jit
    around it.

    ``with_embed=True`` returns ``(logits, embed)`` where ``embed`` is
    the (b, n_bits) f32 GAP vector the head consumes — an intermediate
    the kernel already computes, written to a second output block.  The
    logits path is untouched op-for-op, so fp32 logits are bitwise
    identical with or without the extra output.
    """
    b, l = tiles.shape[0], tiles.shape[1]
    n_bits = packed["head"]["b"].shape[0]
    leaves, treedef = jax.tree.flatten(packed)
    n_out = 2 if with_embed else 1

    def kernel(img_ref, *refs):
        param_refs, out_refs = refs[:-n_out], refs[-n_out:]
        pk = jax.tree.unflatten(treedef, [r[...] for r in param_refs])
        logits, g = extractor_forward_packed_embed(pk, img_ref[...])
        out_refs[0][...] = logits
        if with_embed:
            out_refs[1][...] = g

    out_spec = pl.BlockSpec((1, n_bits), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((b, n_bits), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, l, l, 3), lambda i: (i, 0, 0, 0))] +
                 [_full_spec(x.shape) for x in leaves],
        out_specs=[out_spec] * n_out if with_embed else out_spec,
        out_shape=[out_shape] * n_out if with_embed else out_shape,
        interpret=interpret,
    )(tiles, *leaves)


def _taps_fold(read_tap, entry, cin, j0, nj):
    """Nine tap-shifted dots, N-restricted to weight columns
    [j0, j0+nj), accumulated in the static left-fold order of
    ``conv3x3_mm`` — bit-identical to the full-width conv's columns."""
    w2d = entry["w"][:, j0: j0 + nj]
    scale = entry.get("scale")
    if scale is not None:
        scale = scale[j0: j0 + nj]
    acc = None
    for tap in range(9):
        y = tap_dot(read_tap(tap), w2d, tap, cin, scale)
        acc = y if acc is None else acc + y
    return acc


def _scratch_shapes(bb, l, C):
    """Padded-activation + channel-tile accumulator scratch in VMEM."""
    if pltpu is None:  # pragma: no cover - jax builds without pallas-tpu
        raise NotImplementedError(
            "blocked decode schedule needs pallas TPU scratch shapes; "
            "use the flat schedule (decode_schedule='flat') instead")
    return [pltpu.VMEM((bb, l + 2, l + 2, C), jnp.float32),
            pltpu.VMEM((bb * l * l, C), jnp.float32)]


def fused_extractor_blocked(tiles, packed, *, batch_block: int = 1,
                            channel_tile: int = 0,
                            double_buffer: bool = True,
                            interpret: bool = True,
                            with_embed: bool = False):
    """Blocked-schedule decode: tiles (b, l, l, 3) f32 -> (b, n_bits)
    f32 logits, bitwise equal to ``fused_extractor`` for fp32 packs.

    ``batch_block`` images per grid step (ragged batches are zero-padded
    up to a multiple and the pad rows sliced off — every body op is
    batch-stable, so pad rows cannot perturb real rows).
    ``channel_tile`` bounds the output-column slice each inner dot
    produces (0 = full width).  ``double_buffer`` marks the batch grid
    dimension parallel on TPU so block fetches pipeline; it is a no-op
    under interpret.  ``with_embed=True`` adds a second (b, n_bits)
    output carrying the GAP vector (see ``fused_extractor``); the
    logits ops are unchanged.
    """
    b, l = tiles.shape[0], tiles.shape[1]
    n_bits = packed["head"]["b"].shape[0]
    C = packed["blocks"][0]["w"].shape[-1]
    bb = max(1, min(batch_block, b))
    ct = min(channel_tile, C) if channel_tile else C

    if b % bb:
        pad = bb - b % bb
        padded = jnp.concatenate(
            [tiles, jnp.zeros((pad,) + tiles.shape[1:], tiles.dtype)])
        out = fused_extractor_blocked(
            padded, packed, batch_block=bb, channel_tile=channel_tile,
            double_buffer=double_buffer, interpret=interpret,
            with_embed=with_embed)
        if with_embed:
            return out[0][:b], out[1][:b]
        return out[:b]

    leaves, treedef = jax.tree.flatten(packed)
    M = bb * l * l
    n_out = 2 if with_embed else 1

    def kernel(img_ref, *refs):
        param_refs = refs[:-(n_out + 2)]
        out_refs = refs[-(n_out + 2):-2]
        xp_ref, y_ref = refs[-2], refs[-1]
        out_ref = out_refs[0]
        pk = jax.tree.unflatten(treedef, [r[...] for r in param_refs])
        tiles_blk = img_ref[...]  # (bb, l, l, 3)
        # zero the scratch borders once per step (the interior is
        # overwritten every layer)
        xp_ref[...] = jnp.zeros_like(xp_ref)

        # layer 0 reads the image block directly (cin=3 taps)
        x4 = jnp.pad(tiles_blk, ((0, 0), (1, 1), (1, 1), (0, 0)))

        def read0(tap):
            dy, dx = divmod(tap, 3)
            return jax.lax.slice(
                x4, (0, dy, dx, 0), (bb, dy + l, dx + l, 3)).reshape(M, 3)

        def read_sc(tap):
            dy, dx = divmod(tap, 3)
            return xp_ref[:, dy: dy + l, dx: dx + l, :].reshape(M, C)

        for li, blk in enumerate(pk["blocks"]):
            read_tap, cin = (read0, 3) if li == 0 else (read_sc, C)
            for j0 in range(0, C, ct):
                nj = min(ct, C - j0)
                y_ref[:, j0: j0 + nj] = _taps_fold(
                    read_tap, blk, cin, j0, nj)
            # flat-norm epilogue on the (M, C) GEMM layout
            y = jax.nn.relu(channel_norm(y_ref[...] + blk["b"]))
            xp_ref[:, 1: l + 1, 1: l + 1, :] = y.reshape(bb, l, l, C)

        # to_bits (N=n_bits is small: always full width) + GAP + head
        tb = pk["to_bits"]
        yt = _taps_fold(read_sc, tb, C, 0, n_bits)
        yt = yt.reshape(bb, l, l, n_bits) + tb["b"]
        g = yt.mean(axis=(1, 2))
        if with_embed:
            out_refs[1][...] = g
        cdt = pk["head"]["w"].dtype
        logits = (g.astype(cdt)[:, :, None] * pk["head"]["w"][None]
                  ).astype(jnp.float32).sum(axis=1) + pk["head"]["b"]
        if "corr" in pk and pk["corr"].shape[0] == l * l:
            # highpass = img - box blur, the blur as the same nine-tap
            # sum _box3x3 runs (reusing the layer-0 padded block)
            accb = None
            for tap in range(9):
                dy, dx = divmod(tap, 3)
                xs = jax.lax.slice(x4, (0, dy, dx, 0),
                                   (bb, dy + l, dx + l, 3))
                accb = xs if accb is None else accb + xs
            hp = (tiles_blk - accb * (1.0 / 9.0)).reshape(bb, l * l, 1, 3)
            corr = (hp.astype(cdt) * pk["corr"][None]
                    ).astype(jnp.float32).sum(axis=(1, 3))
            logits = logits + corr * pk["corr_scale"]
        out_ref[...] = logits

    kwargs = {}
    if double_buffer and not interpret and pltpu is not None:
        try:  # pipeline consecutive image blocks on TPU
            kwargs["compiler_params"] = pltpu.TPUCompilerParams(
                dimension_semantics=("parallel",))
        except (AttributeError, TypeError):  # pragma: no cover
            pass

    out_spec = pl.BlockSpec((bb, n_bits), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((b, n_bits), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(b // bb,),
        in_specs=[pl.BlockSpec((bb, l, l, 3), lambda i: (i, 0, 0, 0))] +
                 [_full_spec(x.shape) for x in leaves],
        out_specs=[out_spec] * n_out if with_embed else out_spec,
        out_shape=[out_shape] * n_out if with_embed else out_shape,
        scratch_shapes=_scratch_shapes(bb, l, C),
        interpret=interpret,
        **kwargs,
    )(tiles, *leaves)
