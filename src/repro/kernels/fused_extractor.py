"""Pallas TPU kernel: the fused extractor decode stage.

After PR 2's tile-first ingest, decode — ``extractor_forward``'s 7-block
conv stack, GAP + head, and the spread-spectrum correlation bank — is
the last hot-path stage still running as an unfused XLA graph at full
precision: every conv block round-trips its (l, l, C) activations
through HBM, and QRMark §5.2 identifies exactly this stage as the
GPU-intensive bottleneck that gets extra streams.  This kernel runs the
*whole* forward in one ``pallas_call`` per tile batch:

* each 3x3 conv block is an implicit-im2col MATMUL — nine tap-shifted
  (l*l, C) x (C, C') MXU dots accumulated in static order against the
  pre-packed (9*C, C') weight — with the bias + channel-norm + ReLU
  epilogue fused into the same grid step, so inter-block activations
  never leave VMEM (and no 9x patch matrix is ever materialised);
* the GAP + head and the correlation path (nine-tap box highpass +
  pattern-bank contraction) ride in the same step;
* a precision policy picks the MXU input dtype: fp32 packs are
  bit-identical to the unfused ``extractor_forward`` (oracle parity by
  construction — both run ``extractor_forward_packed`` verbatim), bf16
  packs compute the matmuls at bf16 (2x MXU throughput, half the weight
  traffic) with fp32 accumulation and a fully fp32 epilogue.

One grid step processes one image, mirroring the ingest kernels: the
weights are broadcast to every step and the per-step VMEM working set
stays activation-sized — padded activation + tap slice + accumulator,
~3-4 MB fp32 (~half in bf16) at l=64, C=64, comfortably inside the
~16 MB budget.  Per-step results are written straight to the
(b, n_bits) logits output.

Bit-identity depends on every op in the shared body being batch-stable
(see ``extractor_forward_packed``): the kernel computes image i with
bb=1 shapes, the unfused path with bb=b shapes, and the body is written
so both accumulate identically.  interpret=True executes on CPU (this
container); interpret=False is the TPU target.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.extractor import extractor_forward_packed


def _full_spec(shape):
    """BlockSpec broadcasting one whole (weight) array to every step."""
    nd = len(shape)
    return pl.BlockSpec(shape, lambda i, _nd=nd: (0,) * _nd)


def fused_extractor(tiles, packed, *, interpret: bool = True):
    """tiles (b, l, l, 3) f32 + packed extractor params -> (b, n_bits)
    f32 logits.

    ``packed`` is ``extractor.pack_params(params, dtype)`` — built once
    per pipeline, reused across every batch; its leaf dtypes select the
    fp32 / bf16 compute path.  Not jitted here: callers jit around it.
    """
    b, l = tiles.shape[0], tiles.shape[1]
    n_bits = packed["head"]["b"].shape[0]
    leaves, treedef = jax.tree.flatten(packed)

    def kernel(img_ref, *refs):
        param_refs, out_ref = refs[:-1], refs[-1]
        pk = jax.tree.unflatten(treedef, [r[...] for r in param_refs])
        out_ref[...] = extractor_forward_packed(pk, img_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, l, l, 3), lambda i: (i, 0, 0, 0))] +
                 [_full_spec(x.shape) for x in leaves],
        out_specs=pl.BlockSpec((1, n_bits), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_bits), jnp.float32),
        interpret=interpret,
    )(tiles, *leaves)
