"""Pure-jnp oracles for every Pallas kernel (allclose targets).

These are the semantic ground truth: each kernel sweep test asserts the
pallas_call (interpret mode on CPU) matches these within tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transforms import IMAGENET_MEAN, IMAGENET_STD


# ---------------------------------------------------------------------------
# fused preprocess: Raw -> Resize -> CenterCrop -> Normalize
# ---------------------------------------------------------------------------


def resize_matrix(n_in: int, n_out: int, crop_off: int = 0,
                  n_crop: int = None) -> np.ndarray:
    """Row-interpolation matrix M (n_crop, n_in): out = M @ in reproduces
    bilinear resize (half-pixel centers, antialias=False, edge clamp)
    followed by cropping rows [crop_off, crop_off + n_crop)."""
    n_crop = n_out if n_crop is None else n_crop
    scale = n_in / n_out
    M = np.zeros((n_crop, n_in), np.float32)
    for o in range(n_crop):
        src = (o + crop_off + 0.5) * scale - 0.5
        lo = int(np.floor(src))
        w = src - lo
        lo_c = min(max(lo, 0), n_in - 1)
        hi_c = min(max(lo + 1, 0), n_in - 1)
        M[o, lo_c] += 1.0 - w
        M[o, hi_c] += w
    return M


def fused_preprocess_ref(raw, *, resize: int, crop: int,
                         mean=None, std=None):
    """Oracle: uint8 (b, H, W, 3) -> normalized f32 (b, crop, crop, 3)."""
    mean = IMAGENET_MEAN if mean is None else np.asarray(mean, np.float32)
    std = IMAGENET_STD if std is None else np.asarray(std, np.float32)
    b, H, W, C = raw.shape
    x = raw.astype(jnp.float32) / 255.0
    x = jax.image.resize(x, (b, resize, resize, C), method="bilinear",
                         antialias=False)
    y0 = (resize - crop) // 2
    x = x[:, y0: y0 + crop, y0: y0 + crop, :]
    return (x - jnp.asarray(mean)) / jnp.asarray(std)


def fused_tile_preprocess_ref(raw, offsets, *, resize: int, crop: int,
                              tile: int, mean=None, std=None):
    """Oracle for the tile-first ingest kernel: full staged preprocess
    followed by per-image tile extraction at ``offsets``.  Accepts the
    kernel's both offset forms: (b, 2) -> (b, tile, tile, 3) and the
    (b, k, 2) escalation plan -> (b*k, tile, tile, 3) image-major."""
    from repro.core import tiling
    full = fused_preprocess_ref(raw, resize=resize, crop=crop, mean=mean,
                                std=std)
    offsets = jnp.asarray(offsets, jnp.int32)
    if offsets.ndim == 3:
        return tiling.extract_tiles_k(full, offsets, tile)
    return tiling.extract_tiles(full, offsets, tile)


# ---------------------------------------------------------------------------
# batched GF(2^m) Reed-Solomon syndrome/decode helper
# ---------------------------------------------------------------------------


def gf_mul_ref(a, b, exp, log):
    out = exp[(log[a] + log[b])]
    return jnp.where((a == 0) | (b == 0), 0, out)


def rs_eval_ref(coeffs, xs, exp, log):
    """Batched Horner: coeffs (b, d+1), xs (n,) -> (b, n)."""
    b = coeffs.shape[0]
    acc = jnp.zeros((b, xs.shape[0]), jnp.int32)
    for i in range(coeffs.shape[-1] - 1, -1, -1):
        acc = jnp.bitwise_xor(gf_mul_ref(acc, xs[None, :], exp, log),
                              coeffs[:, i: i + 1])
    return acc


# ---------------------------------------------------------------------------
# extractor conv3x3 block (conv + bias + channel-norm + relu)
# ---------------------------------------------------------------------------


def conv_block_ref(x, w, b, eps: float = 1e-5):
    """x (n, h, w, cin), w (3, 3, cin, cout) SAME conv -> norm -> relu."""
    y = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + b
    mu = y.mean(axis=-1, keepdims=True)
    var = y.var(axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + eps)
    return jax.nn.relu(y)


# ---------------------------------------------------------------------------
# fused extractor decode: conv stack + GAP/head + correlation bank
# ---------------------------------------------------------------------------


def fused_extractor_ref(params, tiles):
    """Semantic oracle for ``kernels.fused_extractor``: the extractor
    forward in the ORIGINAL conv/einsum formulation (lax.conv blocks,
    dense head, depthwise-blur highpass + pattern-bank einsum).

    The kernel and ``extractor_forward`` share the matmul-form body and
    are bitwise identical to each other; this oracle pins both to the
    pre-fusion math within float tolerance (the formulations reorder
    float accumulation, so equality is allclose, not bitwise)."""
    x = tiles
    for blk in params["blocks"]:
        x = conv_block_ref(x, blk["w"], blk["b"])
    x = jax.lax.conv_general_dilated(
        x, params["to_bits"]["w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = x + params["to_bits"]["b"]
    x = x.mean(axis=(1, 2))
    logits = x @ params["head"]["w"] + params["head"]["b"]
    if "corr" in params and tiles.shape[1:3] == params["corr"].shape[1:3]:
        c = tiles.shape[-1]
        k = jnp.tile(jnp.ones((3, 3, 1, 1), jnp.float32) / 9.0,
                     (1, 1, 1, c))
        blur = jax.lax.conv_general_dilated(
            tiles, k, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=c)
        hp = tiles - blur
        corr = jnp.einsum("bhwc,nhwc->bn", hp, params["corr"])
        logits = logits + corr * params["corr_scale"]
    return logits


def fused_extractor_int8_ref(packed, tiles):
    """Semantic oracle for the int8 decode rung: run the shared matmul
    body on *dequantized* fp32 weights (q * scale).

    The real int8 path additionally quantizes activations per row, so
    parity with this oracle is allclose at the activation-quantization
    noise floor (~1/127 relative per tap), NOT bitwise — the test
    contract for int8 is decision-level (hard-bit / RS-decode
    agreement), with this oracle pinning the dequant semantics."""
    from repro.core.extractor import (extractor_forward_packed,
                                      pack_params, unpack_params)
    return extractor_forward_packed(
        pack_params(unpack_params(packed), "fp32"), tiles)
