"""Pallas TPU kernel: batched Reed-Solomon Berlekamp-Welch decode.

The paper keeps RS on the CPU because the classical decoder is branchy;
jax_rs.py already made it branch-free, and this kernel takes the last
step for the serving hot path: one pallas_call decodes a whole block of
codewords in VMEM with *zero gathers* —

* GF(2^4) multiply is computed CARRY-LESSLY (4 AND/shift/XOR partial
  products + 3 reduction steps mod x^4+x+1) instead of log/exp table
  lookups: gathers are the slow path on the TPU VPU, bitwise ops
  vectorise perfectly across the (block, n, n+1) elimination state.
* inverse(a) = a^14 by square-and-multiply (GF(16)* has order 15).
* Berlekamp-Welch = masked-pivot Gaussian elimination, fully unrolled
  over the static 16 columns x 15 rows of the (n, n+1) system.
* the "pick k error-free positions" step replaces argsort with a rank
  prefix-sum + one-hot permutation matmul (branch-free, MXU-able).

Block = 128 codewords/grid step: the elimination state is
(128, 15, 16) int32 = 122 KB — comfortably VMEM-resident.  Oracle:
repro.core.rs.jax_rs (itself validated against the numpy codec).

Default code only (GF(16), n=15, k=12, t=1 — the paper's 48-bit
configuration); other codes fall back to jax_rs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.rs.codec import RSCode, DEFAULT_CODE
from repro.core.rs import gf as gf_np

M, N, K = 4, 15, 12
T = (N - K) // 2  # = 1
NQ = T + 1        # deg(Q) <= t      -> t+1   = 2 coefficients
NN = T + K        # deg(Nu) <= t+k-1 -> t+k   = 13 coefficients
COLS = NQ + NN    # unknowns x = [q_0..q_t, nu_0..nu_{t+k-1}], 15 total
# Berlekamp-Welch: the key equation R_i * Q(x_i) = Nu(x_i) at each of the
# N = 15 evaluation points gives a HOMOGENEOUS linear system A x = 0 with
# shape (N rows, NQ+NN = 15 unknowns).  Whenever <= t symbol errors
# occurred, the true (Q, Nu) pair is a nonzero solution, so rank(A) < 15
# and a nontrivial nullspace vector exists; the kernel runs masked-pivot
# RREF and reads that vector off the first free column — the same
# construction (and tie-breaking rule) as jax_rs, its oracle.


def _gf16_mul(a, b):
    """Carry-less GF(16) multiply, branch-free, elementwise."""
    res = jnp.zeros_like(a)
    for i in range(M):
        res = res ^ (jnp.where((b >> i) & 1 != 0, a << i, 0))
    # reduce bits 6..4 mod x^4 + x + 1 (0b10011)
    for j in (6, 5, 4):
        res = jnp.where((res >> j) & 1 != 0, res ^ (0b10011 << (j - 4)),
                        res)
    return res


def _gf16_inv(a):
    """a^-1 = a^14 (order of GF(16)* is 15); inv(0) := 0."""
    a2 = _gf16_mul(a, a)
    a4 = _gf16_mul(a2, a2)
    a8 = _gf16_mul(a4, a4)
    return _gf16_mul(a8, _gf16_mul(a4, a2))  # a^(8+4+2) = a^14


@functools.lru_cache(maxsize=None)
def _consts():
    exp, _ = gf_np.tables(M)
    xs = exp[:N].astype(np.int32)  # evaluation points alpha^0..alpha^14
    powsQ = np.ones((N, NQ), np.int64)
    powsN = np.ones((N, NN), np.int64)
    g = gf_np.GF(M)
    for i in range(N):
        for j in range(1, NQ):
            powsQ[i, j] = g.mul(powsQ[i, j - 1], int(xs[i]))
        for j in range(1, NN):
            powsN[i, j] = g.mul(powsN[i, j - 1], int(xs[i]))
    return xs, powsQ.astype(np.int32), powsN.astype(np.int32)


def _kernel(bits_ref, xs_ref, powsQ_ref, powsN_ref,
            msg_ref, cw_ref, ok_ref, ncorr_ref):
    bits = bits_ref[...].astype(jnp.int32)  # (B, N*M)
    B = bits.shape[0]
    xs = xs_ref[...]          # (N,)
    powsQ = powsQ_ref[...]    # (N, NQ)
    powsN = powsN_ref[...]    # (N, NN)

    # bits -> symbols (MSB first): weights built from iota (no captured
    # constants allowed in a pallas kernel body)
    w = (1 << (M - 1 - jax.lax.iota(jnp.int32, M)))
    R = (bits.reshape(B, N, M) * w).sum(-1)  # (B, N)

    # build the B-W system A (B, N, COLS)
    A = jnp.concatenate(
        [_gf16_mul(R[:, :, None], powsQ[None]),
         jnp.broadcast_to(powsN[None], (B, N, NN)).astype(jnp.int32)],
        axis=2)

    # masked-pivot RREF, unrolled over the static COLS columns
    rows = N
    cols = COLS
    row_idx = jax.lax.iota(jnp.int32, rows)
    pivot_col = jnp.full((B, rows), cols, jnp.int32)
    r = jnp.zeros((B,), jnp.int32)
    for c in range(cols):
        colv = A[:, :, c]  # (B, rows)
        eligible = (row_idx[None] >= r[:, None]) & (colv != 0)
        has = eligible.any(axis=1)  # (B,)
        pr = jnp.argmax(eligible, axis=1)  # first eligible row
        # swap rows r <-> pr (select form; r == pr degenerates safely)
        onehot_r = row_idx[None] == r[:, None]
        onehot_p = row_idx[None] == pr[:, None]
        Ar = (A * onehot_r[..., None]).sum(1)  # (B, cols)
        Ap = (A * onehot_p[..., None]).sum(1)
        swp = has[:, None, None]
        A = jnp.where(swp & onehot_r[..., None], Ap[:, None, :], A)
        A = jnp.where(swp & onehot_p[..., None] & ~onehot_r[..., None],
                      Ar[:, None, :], A)
        # normalise pivot row
        piv = (A[:, :, c] * onehot_r).sum(1)  # (B,)
        inv = _gf16_inv(piv)
        Arow = (A * onehot_r[..., None]).sum(1)
        Arow_n = _gf16_mul(Arow, inv[:, None])
        A = jnp.where(swp & onehot_r[..., None], Arow_n[:, None, :], A)
        # eliminate column c from all other rows
        factors = jnp.where((~onehot_r) & has[:, None], A[:, :, c], 0)
        Apiv = (A * onehot_r[..., None]).sum(1)  # (B, cols)
        A = A ^ _gf16_mul(factors[..., None], Apiv[:, None, :])
        pivot_col = jnp.where(onehot_r & has[:, None],
                              jnp.int32(c), pivot_col)
        r = jnp.minimum(r + has.astype(jnp.int32), rows)

    # nullspace vector: first free column f; x[f] = 1,
    # x[pivot_col[row]] = A[row, f] for every pivot row (char 2: -a == a).
    # Pivot columns are distinct and never equal f, so XOR-accumulation
    # of the one-hot contributions is exact.
    col_ids = jax.lax.iota(jnp.int32, cols)
    is_pivot = (pivot_col[:, :, None] == col_ids[None, None, :]).any(1)
    free = jnp.argmin(is_pivot.astype(jnp.int32), axis=1)  # (B,)
    x = (col_ids[None] == free[:, None]).astype(jnp.int32)  # (B, cols)
    vals = jnp.take_along_axis(
        A, jnp.broadcast_to(free[:, None, None], (B, rows, 1)),
        axis=2)[:, :, 0]  # A[:, row, free] -> (B, rows)
    scatter = (pivot_col[:, :, None] == col_ids[None, None, :])
    x = x ^ (scatter * vals[:, :, None]).sum(1)

    Q = x[:, :NQ]  # (B, NQ)
    # Q(X_i) via unrolled Horner
    qx = jnp.zeros((B, N), jnp.int32)
    for j in range(NQ - 1, -1, -1):
        qx = _gf16_mul(qx, xs[None]) ^ Q[:, j:j + 1]
    q_nonzero = (Q != 0).any(axis=1)
    err = (qx == 0) & q_nonzero[:, None]  # (B, N)

    # pick K error-free positions: rank prefix-sum + one-hot permutation
    okpos = (~err).astype(jnp.int32)  # (B, N)
    rank = jnp.cumsum(okpos, axis=1) - okpos  # rank among correct ones
    sel = (okpos * (rank < K)) == 1  # (B, N) -> exactly K true (if >=K ok)
    slot = jnp.where(sel, rank, K)  # (B, N) in [0..K]
    perm = (slot[:, :, None]
            == jax.lax.iota(jnp.int32, K)[None, None, :]
            ).astype(jnp.int32)  # (B, N, K)
    xs_sel = (perm * xs[None, :, None]).sum(1)  # (B, K)
    ys_sel = (perm * R[:, :, None]).sum(1)      # (B, K)

    # Lagrange re-interpolation evaluated at all N points (unrolled)
    # denom_i = prod_{j!=i} (Xs_i ^ Xs_j); wgt_i = y_i * inv(denom_i)
    denom = jnp.ones((B, K), jnp.int32)
    for j in range(K):
        d = xs_sel ^ xs_sel[:, j:j + 1]
        d = jnp.where(jax.lax.iota(jnp.int32, K)[None] == j, 1, d)
        denom = _gf16_mul(denom, d)
    wgt = _gf16_mul(ys_sel, _gf16_inv(denom))  # (B, K)
    # P(x) at each eval point: sum_i wgt_i * prod_{j != i} (x ^ Xs_j)
    P_at = jnp.zeros((B, N), jnp.int32)
    for i in range(K):
        numer = jnp.ones((B, N), jnp.int32)
        for j in range(K):
            if j == i:
                continue
            numer = _gf16_mul(numer, xs[None] ^ xs_sel[:, j:j + 1])
        P_at = P_at ^ _gf16_mul(numer, wgt[:, i:i + 1])

    n_err = (P_at != R).sum(axis=1)
    ok = (n_err <= T) & q_nonzero
    cw = jnp.where(ok[:, None], P_at, R)  # (B, N)
    # symbols -> bits
    sh = M - 1 - jax.lax.iota(jnp.int32, M)
    cw_bits = ((cw[:, :, None] >> sh) & 1).reshape(B, N * M)
    msg_ref[...] = cw_bits[:, : K * M]
    cw_ref[...] = cw_bits
    ok_ref[...] = ok.astype(jnp.int32)
    ncorr_ref[...] = jnp.where(ok, n_err, -1).astype(jnp.int32)


def rs_decode_batch(bits, *, code: RSCode = DEFAULT_CODE,
                    block: int = 128, interpret: bool = True):
    """bits (B, n*m) int -> dict(message_bits, codeword_bits, ok,
    n_corrected).  Pallas kernel for the default (15,12) GF(16) code."""
    if (code.m, code.n, code.k) != (M, N, K):
        from repro.core.rs import jax_rs
        return jax_rs.make_batch_decoder(code)(bits)
    B = bits.shape[0]
    blk = min(block, B)
    Bp = -(-B // blk) * blk
    bits_p = jnp.pad(bits.astype(jnp.int32), ((0, Bp - B), (0, 0)))
    xs_np, powsQ_np, powsN_np = _consts()
    grid = (Bp // blk,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((blk, N * M), lambda i: (i, 0)),
                  pl.BlockSpec((N,), lambda i: (0,)),
                  pl.BlockSpec((N, NQ), lambda i: (0, 0)),
                  pl.BlockSpec((N, NN), lambda i: (0, 0))],
        out_specs=[
            pl.BlockSpec((blk, K * M), lambda i: (i, 0)),
            pl.BlockSpec((blk, N * M), lambda i: (i, 0)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, K * M), jnp.int32),
            jax.ShapeDtypeStruct((Bp, N * M), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=interpret,
    )(bits_p, jnp.asarray(xs_np), jnp.asarray(powsQ_np),
      jnp.asarray(powsN_np))
    msg, cw, ok, ncorr = out
    return {"message_bits": msg[:B], "codeword_bits": cw[:B],
            "ok": ok[:B].astype(bool), "n_corrected": ncorr[:B]}
