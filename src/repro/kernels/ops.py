"""Jit'd public wrappers for the Pallas kernels.

Import surface used by the rest of the framework; each op dispatches to
the Pallas kernel (interpret mode on CPU, compiled on TPU) and has a
pure-jnp oracle in ref.py.
"""
from __future__ import annotations

import jax

from repro.kernels.fused_preprocess import fused_preprocess as \
    _fused_preprocess
from repro.kernels.fused_tile_preprocess import fused_tile_preprocess as \
    _fused_tile_preprocess


def fused_preprocess(raw, *, resize: int = 256, crop: int = 256,
                     mean=None, std=None):
    """Fused Resize->CenterCrop->Normalize (QRMark App. B.1, TPU form)."""
    interpret = jax.default_backend() != "tpu"
    return _fused_preprocess(raw, resize=resize, crop=crop, mean=mean,
                             std=std, interpret=interpret)


def fused_tile_preprocess(raw, offsets, *, resize: int = 256,
                          crop: int = 256, tile: int = 64,
                          mean=None, std=None):
    """Tile-first fused ingest: Resize->Crop->Normalize->Tile-extract in
    one kernel — the (b, tile, tile, 3) decode input directly, bit-equal
    to ``fused_preprocess`` + ``tiling.extract_tiles`` at ``offsets``.
    Offsets may also be a (b, k, 2) escalation plan, emitting
    (b*k, tile, tile, 3) image-major so escalated tiles ride the same
    MXU path (see ``tiling.escalation_offsets``)."""
    interpret = jax.default_backend() != "tpu"
    return _fused_tile_preprocess(raw, offsets, resize=resize, crop=crop,
                                  tile=tile, mean=mean, std=std,
                                  interpret=interpret)


def fused_extractor(tiles, packed, schedule=None, with_embed=False):
    """Fused decode: the whole extractor forward (im2col-matmul conv
    blocks + GAP/head + correlation bank) in one kernel launch per tile
    batch.  ``packed`` = ``extractor.pack_params(params, dtype)``; its
    dtype selects the fp32 (bit-exact vs ``extractor_forward``), bf16
    (MXU compute, fp32 accumulation) or int8 (per-channel-scaled
    weights, int32 accumulation) path.

    ``schedule`` picks the kernel blocking: ``None`` runs the flat
    grid=(b,) kernel; a ``kernels.autotune.Schedule`` (or anything with
    ``batch_block`` / ``channel_tile`` / ``double_buffer`` attributes)
    runs the blocked kernel — fp32 output is bitwise identical either
    way, so the schedule is purely a throughput knob.

    ``with_embed=True`` returns ``(logits, embed)``: the GAP vector is
    emitted as a second kernel output (no extra arithmetic; logits
    bitwise unchanged) — the serving tier's near-duplicate cache key."""
    interpret = jax.default_backend() != "tpu"
    if schedule is None:
        from repro.kernels.fused_extractor import fused_extractor as _fx
        return _fx(tiles, packed, interpret=interpret,
                   with_embed=with_embed)
    from repro.kernels.fused_extractor import fused_extractor_blocked
    return fused_extractor_blocked(
        tiles, packed, batch_block=schedule.batch_block,
        channel_tile=schedule.channel_tile,
        double_buffer=schedule.double_buffer, interpret=interpret,
        with_embed=with_embed)


def rs_decode(bits, *, code=None):
    """Batched Berlekamp-Welch decode (Pallas kernel for the default
    (15,12) GF(16) code; jax_rs fallback otherwise)."""
    from repro.core.rs.codec import DEFAULT_CODE
    from repro.kernels.rs_decode import rs_decode_batch
    interpret = jax.default_backend() != "tpu"
    return rs_decode_batch(bits, code=code or DEFAULT_CODE,
                           interpret=interpret)
