"""Autotune harness for the blocked decode schedule.

The blocked kernel (``fused_extractor_blocked``) exposes a small
schedule space — batch block x channel tile x buffering — whose winner
depends on backend, compute dtype, tile size and network width: on TPU
larger batch blocks amortise weight residency, on CPU (interpret mode)
the win comes from the padded-activation scratch + flat-norm epilogue
at bb=1 and extra blocking mostly adds cache pressure.  Rather than
hard-code per-backend tables, this module sweeps the candidates on a
representative workload, times each with warmup + median, and persists
the winner in a small JSON cache keyed by
``backend|dtype|tile|channels|depth|n_bits`` — ``serve.py --autotune``
populates it at deploy time and ``--schedule auto`` (or
``DetectionConfig.decode_schedule="auto"``) loads it at service build.

fp32 schedules are interchangeable bitwise (the blocked kernel is
bit-identical to the flat one at every candidate), so a stale or
missing cache can always fall back to the flat schedule — loudly, never
silently.

CLI::

    PYTHONPATH=src python -m repro.kernels.autotune \
        --tile 64 --batch 8 --dtype fp32 --cache experiments/autotune/decode_schedules.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

CACHE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One blocked-kernel schedule point.

    ``batch_block`` images per grid step, ``channel_tile`` output
    columns per inner dot (0 = full width), ``double_buffer`` requests
    parallel grid semantics on TPU.  The string form ("bb2-ct32-db")
    is what the JSON cache and the ``--schedule`` flag speak.
    """
    batch_block: int = 1
    channel_tile: int = 0
    double_buffer: bool = True

    def to_string(self) -> str:
        s = f"bb{self.batch_block}-ct{self.channel_tile}"
        return s + "-db" if self.double_buffer else s

    @classmethod
    def from_string(cls, s: str) -> "Schedule":
        parts = s.strip().lower().split("-")
        if (len(parts) not in (2, 3)
                or not parts[0].startswith("bb")
                or not parts[1].startswith("ct")
                or (len(parts) == 3 and parts[2] != "db")):
            raise ValueError(
                f"bad schedule string {s!r}: expected 'flat', 'auto' or "
                f"'bb<N>-ct<N>[-db]' (e.g. 'bb2-ct32-db')")
        try:
            bb, ct = int(parts[0][2:]), int(parts[1][2:])
        except ValueError:
            raise ValueError(f"bad schedule string {s!r}: "
                             f"non-integer block sizes") from None
        if bb < 1 or ct < 0:
            raise ValueError(f"bad schedule string {s!r}: "
                             f"need bb >= 1 and ct >= 0")
        return cls(bb, ct, len(parts) == 3)


def schedule_key(*, backend: str, dtype: str, tile: int, channels: int,
                 depth: int, n_bits: int) -> str:
    """Cache key: every axis that changes the winner (or the kernel)."""
    return f"{backend}|{dtype}|t{tile}|c{channels}|d{depth}|n{n_bits}"


# cache_lookup's "no entry" sentinel: distinct from None, because the
# cached WINNER can legitimately be the flat schedule (represented as
# None everywhere a kernel schedule is passed around)
MISS = object()


def _from_cached(s: str):
    """Cached schedule string -> kernel schedule ("flat" -> None)."""
    return None if s == "flat" else Schedule.from_string(s)


def candidate_schedules(batch: int, channels: int,
                        backend: str = None, quick: bool = False):
    """The sweep space for one key.  TPU explores batch blocks up to the
    batch (weight-residency amortisation) and buffering on/off; CPU
    interpret keeps the space small — blocking past bb=2 only adds
    cache pressure there."""
    backend = backend or jax.default_backend()
    bbs = [b for b in (1, 2, 4, 8) if b <= max(batch, 1)]
    cts = [0, channels // 2]
    dbs = (True, False) if backend == "tpu" else (True,)
    if quick:
        bbs, cts, dbs = bbs[:2], [0], (True,)
    return [Schedule(bb, ct, db)
            for bb in bbs for ct in cts for db in dbs]


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call after warmup (median resists the
    one-off scheduling spikes a mean would absorb)."""
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    return statistics.median(samples)


def sweep(packed, tile: int, batch: int, *, dtype: str = "fp32",
          iters: int = 3, warmup: int = 1, candidates=None,
          quick: bool = False, log=print) -> dict:
    """Time the flat kernel and every candidate blocked schedule on a
    synthetic (batch, tile, tile, 3) workload; return the record that
    goes into the cache.  Flat itself is a candidate: when every
    blocked schedule loses to it (small tiles on CPU, where per-step
    interpret overhead eats the scratch win), the cached winner is
    "flat" — the tuner never crowns a schedule slower than the
    baseline.  The record keeps the full swept list either way."""
    from repro.kernels import ops as kops

    backend = jax.default_backend()
    channels = packed["blocks"][0]["w"].shape[-1]
    key = jax.random.key(0)
    tiles = jax.random.uniform(key, (batch, tile, tile, 3),
                               jnp.float32, -1.0, 1.0)
    flat = jax.jit(lambda t: kops.fused_extractor(t, packed))
    wall_flat = time_fn(flat, tiles, iters=iters, warmup=warmup)
    log(f"[autotune] flat: {wall_flat * 1e3:.1f}ms "
        f"(tile={tile} batch={batch} dtype={dtype} backend={backend})")

    candidates = candidates or candidate_schedules(
        batch, channels, backend, quick=quick)
    swept = [{"schedule": "flat", "wall_ms": wall_flat * 1e3,
              "speedup_vs_flat": 1.0}]
    best, best_wall = "flat", wall_flat
    for sc in candidates:
        fn = jax.jit(lambda t, _sc=sc: kops.fused_extractor(
            t, packed, schedule=_sc))
        wall = time_fn(fn, tiles, iters=iters, warmup=warmup)
        swept.append({"schedule": sc.to_string(),
                      "wall_ms": wall * 1e3,
                      "speedup_vs_flat": wall_flat / wall})
        log(f"[autotune]   {sc.to_string():<14} {wall * 1e3:8.1f}ms  "
            f"speedup={wall_flat / wall:.3f}")
        if wall < best_wall:
            best, best_wall = sc.to_string(), wall
    return {
        "schedule": best,
        "wall_flat_ms": wall_flat * 1e3,
        "wall_best_ms": best_wall * 1e3,
        "speedup_vs_flat": wall_flat / best_wall,
        "batch": batch,
        "swept": swept,
    }


# ---------------------------------------------------------------------------
# JSON cache
# ---------------------------------------------------------------------------


def load_cache(path) -> dict:
    """Load the schedule cache; a corrupt or stale (version-mismatched)
    file degrades to an empty cache with a LOUD warning — every caller
    then falls back to the flat schedule, which is always correct."""
    path = Path(path)
    if not path.exists():
        return {"version": CACHE_VERSION, "entries": {}}
    try:
        data = json.loads(path.read_text())
    except (json.JSONDecodeError, OSError) as e:
        print(f"[autotune] WARNING: schedule cache {path} is corrupt "
              f"({e}); ignoring it and falling back to the flat "
              f"schedule", file=sys.stderr)
        return {"version": CACHE_VERSION, "entries": {}}
    if (not isinstance(data, dict)
            or data.get("version") != CACHE_VERSION
            or not isinstance(data.get("entries"), dict)):
        print(f"[autotune] WARNING: schedule cache {path} has stale or "
              f"unknown format (version="
              f"{data.get('version') if isinstance(data, dict) else '?'}"
              f", want {CACHE_VERSION}); ignoring it and falling back "
              f"to the flat schedule", file=sys.stderr)
        return {"version": CACHE_VERSION, "entries": {}}
    return data


def save_cache(path, cache) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(cache, indent=2, sort_keys=True) + "\n")


def cache_lookup(cache: dict, key: str):
    """Cached winner for ``key``: a blocked ``Schedule``, None (the
    winner was flat), or the ``MISS`` sentinel when there is no entry;
    an unparseable stored schedule is reported loudly and treated as a
    miss (flat fallback)."""
    entry = cache.get("entries", {}).get(key)
    if entry is None:
        return MISS
    try:
        return _from_cached(entry["schedule"])
    except (ValueError, KeyError, TypeError) as e:
        print(f"[autotune] WARNING: cache entry for {key!r} is invalid "
              f"({e}); falling back to the flat schedule",
              file=sys.stderr)
        return MISS


def autotune(packed, *, tile: int, batch: int, dtype: str,
             cache_path, iters: int = 3, warmup: int = 1,
             quick: bool = False, force: bool = False, log=print):
    """Cache-through autotune: return the winning Schedule for this
    (backend, dtype, tile, net) key, sweeping and persisting only on a
    cache miss (or ``force``).  Prints "cache hit" on reuse so smoke
    tests can assert the sweep was skipped."""
    depth = len(packed["blocks"])
    channels = packed["blocks"][0]["w"].shape[-1]
    n_bits = packed["head"]["b"].shape[0]
    key = schedule_key(backend=jax.default_backend(), dtype=dtype,
                       tile=tile, channels=channels, depth=depth,
                       n_bits=n_bits)
    cache = load_cache(cache_path)
    if not force:
        hit = cache_lookup(cache, key)
        if hit is not MISS:
            log(f"[autotune] cache hit: {key} -> "
                f"{'flat' if hit is None else hit.to_string()}")
            return hit
    record = sweep(packed, tile, batch, dtype=dtype, iters=iters,
                   warmup=warmup, quick=quick, log=log)
    cache["entries"][key] = record
    save_cache(cache_path, cache)
    log(f"[autotune] cached: {key} -> {record['schedule']} "
        f"(speedup {record['speedup_vs_flat']:.3f} vs flat) -> "
        f"{cache_path}")
    return _from_cached(record["schedule"])


def resolve_schedule(spec: str, *, dtype: str, tile: int, channels: int,
                     depth: int, n_bits: int, cache_path=""):
    """DetectionConfig.decode_schedule -> kernel schedule.

    "flat" (default) -> None (the flat kernel); "auto" -> cache lookup,
    with a printed hint + flat fallback when the cache has no entry for
    this key; "bb<N>-ct<N>[-db]" -> that explicit schedule.  Raises
    ValueError on anything else so config typos fail at build, not in
    the hot path."""
    spec = (spec or "flat").strip().lower()
    if spec == "flat":
        return None
    if spec == "auto":
        key = schedule_key(backend=jax.default_backend(), dtype=dtype,
                           tile=tile, channels=channels, depth=depth,
                           n_bits=n_bits)
        if not cache_path:
            print(f"[autotune] decode_schedule='auto' but no autotune "
                  f"cache path configured; run `python -m "
                  f"repro.kernels.autotune` or `serve --autotune` and "
                  f"set autotune_cache.  Falling back to the flat "
                  f"schedule for {key}", file=sys.stderr)
            return None
        sc = cache_lookup(load_cache(cache_path), key)
        if sc is MISS:
            print(f"[autotune] no cached schedule for {key} in "
                  f"{cache_path}; run `python -m repro.kernels.autotune`"
                  f" or `serve --autotune` to populate it.  Falling "
                  f"back to the flat schedule", file=sys.stderr)
            return None
        return sc
    return Schedule.from_string(spec)


def main(argv=None):
    from repro.core.extractor import init_extractor, pack_params

    ap = argparse.ArgumentParser(
        description="Sweep blocked decode schedules and cache winners")
    ap.add_argument("--tile", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--dtype", default="fp32",
                    choices=("fp32", "bf16", "int8"))
    ap.add_argument("--channels", type=int, default=64)
    ap.add_argument("--depth", type=int, default=7)
    ap.add_argument("--n-bits", type=int, default=60)
    ap.add_argument("--cache",
                    default="experiments/autotune/decode_schedules.json")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--quick", action="store_true",
                    help="tiny candidate set (CI smoke)")
    ap.add_argument("--force", action="store_true",
                    help="re-sweep even on a cache hit")
    args = ap.parse_args(argv)

    params = init_extractor(jax.random.key(2), n_bits=args.n_bits,
                            channels=args.channels, depth=args.depth,
                            tile=args.tile)
    packed = pack_params(params, args.dtype)
    sc = autotune(packed, tile=args.tile, batch=args.batch,
                  dtype=args.dtype, cache_path=args.cache,
                  iters=args.iters, warmup=args.warmup,
                  quick=args.quick, force=args.force)
    print(f"[autotune] schedule: "
          f"{'flat' if sc is None else sc.to_string()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
