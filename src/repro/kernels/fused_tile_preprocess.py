"""Pallas TPU kernel: tile-first fused Resize -> Crop -> Normalize ->
Tile-extract.

The staged ingest (``fused_preprocess.py``) resizes/normalises the FULL
image even though the qrmark decode stage reads exactly one l x l tile of
it — at the default 256^2 image / 64^2 tile that is ~16x more output (and
>4x more MXU FLOPs) than the pipeline ever consumes.  This kernel makes
the *selected tile* the unit of ingest work: because the staged transform
is two interpolation matmuls per channel,

    full[c] = scale_c * (Ry @ img[:, :, c] @ Rx) + bias_c,

the (y, x) tile of the output only needs rows [y, y+l) of ``Ry`` and
columns [x, x+l) of ``Rx`` — output row i depends on nothing but row i of
``Ry``, so slicing the interpolation matrices *before* the matmuls yields
bit-identical values to slicing the full preprocessed image after them,
while shrinking the per-image FLOPs from

    3 * (crop*H*W + crop*W*crop)   to   3 * (l*H*W + l*W*l).

Per-image tile offsets (already derived from per-image fold_in keys by
``tiling.per_image_offsets``, so they are available *before* ingest) are
applied as a vmapped ``dynamic_slice`` over the shared (crop, H)/(W, crop)
matrices on the way into the kernel; the kernel itself is two small MXU
matmuls per channel per grid step and writes the (b, l, l, 3) decode
input directly — the full preprocessed image is never materialised.

Multi-tile escalation form: offsets may also be (b, k, 2) — k tiles per
image (``tiling.escalation_offsets`` plans).  The grid becomes b*k steps
whose image block index is ``step // k``, so each raw image is read k
times from its single HBM copy (never replicated host-side) and the
kernel emits (b*k, l, l, 3) tile-major per image — escalated tiles ride
exactly the same MXU path as the single-tile hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.transforms import IMAGENET_MEAN, IMAGENET_STD
from repro.kernels.fused_preprocess import interp_affine, interp_matrices


def _kernel(img_ref, ry_ref, rx_ref, scale_ref, bias_ref, out_ref):
    img = img_ref[0].astype(jnp.float32)          # (H, W, 3)
    # ry (tile, H) / rx (W, tile) are this image's pre-sliced matrices;
    # the math is the staged kernel's interp_affine, shared verbatim
    out_ref[0] = interp_affine(img, ry_ref[0], rx_ref[0],
                               scale_ref[...], bias_ref[...])


def slice_interp_matrices(offsets, *, H: int, W: int, resize: int,
                          crop: int, tile: int):
    """Per-image (tile, H) row / (W, tile) column slices of the shared
    interpolation matrices at the given (b, 2) int32 tile offsets
    (offsets live in the cropped image's coordinate space)."""
    ry, rx = interp_matrices(H, W, resize=resize, crop=crop)

    def one(o):
        return (jax.lax.dynamic_slice(ry, (o[0], 0), (tile, H)),
                jax.lax.dynamic_slice(rx, (0, o[1]), (W, tile)))

    return jax.vmap(one)(offsets.astype(jnp.int32))


def fused_tile_preprocess(raw, offsets, *, resize: int = 256,
                          crop: int = 256, tile: int = 64,
                          mean=None, std=None, interpret: bool = True):
    """uint8 (b, H, W, 3) + tile offsets -> f32 tiles.

    ``offsets`` is (b, 2) — one tile per image, output
    (b, tile, tile, 3) — or (b, k, 2) — a k-tile escalation plan per
    image, output (b*k, tile, tile, 3) flattened image-major (rows
    [i*k, (i+1)*k) are image i's tiles).  Either way each output tile
    equals ``extract_tiles(fused_preprocess(raw), <its offset>, tile)``
    bit for bit, without materialising the (b, crop, crop, 3)
    intermediate; the multi-tile grid reads each raw image block k
    times rather than replicating it.  interpret=True executes on CPU
    (this container); interpret=False is the TPU target.  Not jitted
    here: callers jit around it (the interpolation matrices are host
    constants).
    """
    mean = np.asarray(IMAGENET_MEAN if mean is None else mean, np.float32)
    std = np.asarray(IMAGENET_STD if std is None else std, np.float32)
    b, H, W, C = raw.shape
    assert C == 3
    assert tile <= crop, f"tile {tile} exceeds crop {crop}"
    offsets = jnp.asarray(offsets, jnp.int32)
    k = offsets.shape[1] if offsets.ndim == 3 else 1
    n = b * k
    ry_t, rx_t = slice_interp_matrices(
        offsets.reshape(n, 2), H=H, W=W, resize=resize, crop=crop,
        tile=tile)
    scale = jnp.asarray(1.0 / (255.0 * std))
    bias = jnp.asarray(-mean / std)

    return pl.pallas_call(
        _kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, H, W, 3), lambda i: (i // k, 0, 0, 0)),
            pl.BlockSpec((1, tile, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, W, tile), lambda i: (i, 0, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile, tile, 3), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, tile, tile, 3), jnp.float32),
        interpret=interpret,
    )(raw, ry_t, rx_t, scale, bias)
