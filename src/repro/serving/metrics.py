"""Serving metrics registry: counters, gauges, and windowed latency
percentiles.

Deliberately dependency-free (no prometheus client in the container):
a :class:`MetricsRegistry` is a thread-safe dict of counters/gauges
plus bounded reservoirs for distributions.  ``snapshot()`` renders the
report the server and the fig11/fig12 benchmarks consume — queue
depth, batch occupancy, p50/p95/p99 request latency, throughput, and
the escalation telemetry (``images_escalated`` / ``escalation_batches``
counters, the ``tiles_per_image`` distribution; the server derives
``escalation_rate`` from them in ``stats()``).

Cache / admission telemetry: the server counts cache hits by tier
(``cache_hit_exact`` / ``cache_hit_embed`` / ``cache_miss`` plus
``dedup_coalesced`` for in-flight coalescing) and observes request
latency both overall (``request_latency_s``) and per priority class
(``request_latency_<class>_s`` — p50/p95 per class come out of the
same snapshot machinery).  ``snapshot()`` derives ``rejection_rate``
(rejected / offered) and the request-level ``cache_hit_rate`` from the
counters so every consumer reads one definition.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, Optional

# distributions keep the most recent N observations — enough for stable
# tail percentiles at benchmark scale without unbounded growth
_RESERVOIR = 8192


def aggregate_counters(snapshots) -> Dict[str, float]:
    """Sum the ``counters`` dicts of several :meth:`MetricsRegistry
    .snapshot` outputs — the fleet-level rollup (per-replica counters
    are exact and additive; latency distributions are NOT additive and
    stay per-replica, the router observes its own fleet-wide ones)."""
    out: Dict[str, float] = {}
    for snap in snapshots:
        for k, v in snap.get("counters", {}).items():
            out[k] = out.get(k, 0.0) + v
    return out


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of an already-sorted list (q in [0,100])."""
    if not sorted_vals:
        return float("nan")
    idx = max(0, min(len(sorted_vals) - 1,
                     int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return float(sorted_vals[idx])


class MetricsRegistry:
    """Thread-safe counters / gauges / distributions for the server."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._dists: Dict[str, Deque[float]] = {}
        self._t0 = time.perf_counter()

    # -- primitives -----------------------------------------------------
    def count(self, name: str, delta: float = 1.0):
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + delta

    def gauge(self, name: str, value: float):
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float):
        with self._lock:
            d = self._dists.get(name)
            if d is None:
                d = self._dists[name] = deque(maxlen=_RESERVOIR)
            d.append(float(value))

    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0.0)

    # -- the serving report ----------------------------------------------
    def snapshot(self) -> dict:
        """One dict with everything: counters, gauges, and per
        distribution n/mean/p50/p95/p99 (latencies in the unit they
        were observed in — the server observes seconds)."""
        with self._lock:
            wall = time.perf_counter() - self._t0
            out = {"wall_s": wall,
                   "counters": dict(self._counters),
                   "gauges": dict(self._gauges)}
            dists = {k: sorted(v) for k, v in self._dists.items()}
        for name, vals in dists.items():
            out[name] = {
                "n": len(vals),
                "mean": (sum(vals) / len(vals)) if vals else float("nan"),
                "p50": percentile(vals, 50),
                "p95": percentile(vals, 95),
                "p99": percentile(vals, 99),
            }
        done = out["counters"].get("requests_completed", 0.0)
        imgs = out["counters"].get("images_completed", 0.0)
        out["throughput_rps"] = done / wall if wall > 0 else 0.0
        out["throughput_ips"] = imgs / wall if wall > 0 else 0.0
        c = out["counters"]
        # admission funnel: rejected vs everything the server accepted
        # (admitted covers cache hits and dedup followers too — they
        # were accepted work, just not executed)
        rej = c.get("requests_rejected", 0.0)
        adm = c.get("requests_admitted", 0.0)
        out["rejection_rate"] = rej / (rej + adm) if rej + adm else 0.0
        # cache funnel (request level): exact hits + coalesced
        # followers avoided an execution; misses ran the pipeline.
        # Tier-2 embedding hits are per-IMAGE escalation short-circuits
        # and are reported as their own counter, not folded in here.
        hits = c.get("cache_hit_exact", 0.0) + c.get("dedup_coalesced",
                                                     0.0)
        lookups = hits + c.get("cache_miss", 0.0)
        out["cache_hit_rate"] = hits / lookups if lookups else 0.0
        return out

    def reset_clock(self):
        """Restart the throughput window (after warmup, before load)."""
        with self._lock:
            self._t0 = time.perf_counter()

    def reset(self):
        """Drop everything (counters, gauges, distributions) and restart
        the clock — between sweep points that reuse one server so each
        offered-load measurement stands alone."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._dists.clear()
            self._t0 = time.perf_counter()
