"""Online request-level serving runtime.

The offline engines (``repro.core.detect``) consume a batch stream that
exists up front; this package is the regime a provenance-checking
service actually lives in — requests arriving over time, queueing,
coalescing, and tail latency:

* :mod:`repro.serving.batcher` — dynamic micro-batching with
  depth-bounded, SLO-tiered admission control (priority classes with
  per-class deadlines);
* :mod:`repro.serving.cache` — content-addressed result caching:
  exact sha256 tier, near-duplicate embedding tier, and the
  dedup-in-flight table;
* :mod:`repro.serving.server` — :class:`DetectionServer`: per-request
  futures over a persistent service-mode lane executor, straggler
  re-execution, live lane reallocation;
* :mod:`repro.serving.replica` — :class:`Replica`: one server wrapped
  for fleet membership (identity, optional device pin, health,
  injectable :class:`FaultPlan` fault hooks);
* :mod:`repro.serving.router` — :class:`FleetRouter`: rendezvous
  content-digest routing over N replicas, spill-over on backpressure,
  crash re-execution, rolling reconfigure;
* :mod:`repro.serving.metrics` — queue depth / batch occupancy /
  latency percentiles / throughput / cache + admission registry.
"""
from repro.serving.batcher import (AdmissionError, BatcherConfig,
                                   MicroBatcher)
from repro.serving.cache import (EmbeddingCache, InFlightTable,
                                 ResultCache)
from repro.serving.metrics import MetricsRegistry
from repro.serving.replica import FaultPlan, Replica, ReplicaCrashed
from repro.serving.router import FleetRouter
from repro.serving.server import DetectionServer

__all__ = ["AdmissionError", "BatcherConfig", "MicroBatcher",
           "ResultCache", "EmbeddingCache", "InFlightTable",
           "MetricsRegistry", "DetectionServer",
           "Replica", "FaultPlan", "ReplicaCrashed", "FleetRouter"]
