"""Online request-level detection server.

``DetectionServer`` is the deployment regime the paper's system layer
targets (provenance checks under heavy user traffic): requests arrive
over time, are coalesced by the dynamic micro-batcher, flow through a
**persistent service-mode lane executor** running the same stage
registry as every offline engine, and scatter back to per-request
futures the moment their micro-batch completes.

Request lifecycle::

    submit(images, key) ──► content cache (tier-1 exact sha256 hit →
        resolve immediately; identical request in flight → coalesce
        onto it) ──► admission (per-class depth bound; empty/oversized
        rejected) ──► MicroBatcher class queues (priority pop, tiered
        deadlines) ──► deadline/size-triggered micro-batch ──►
        service-mode LaneExecutor (ingest ► decode ► rs, N lanes
        each) ──► tier-2 embedding cache (escalation short-circuit)
        ──► result scatter (cache fill + dedup fan-out) ──►
        RequestHandle.result()

Content-addressed caching (``DetectionConfig.cache_exact`` /
``cache_embedding_threshold``, machinery in ``serving.cache``): tier 1
keys on a cryptographic content digest (sha256 over shape + canonical
pixel bytes, host-side, pre-admission — collision-free, so a hit can
only ever serve the same image's result) joined with the request
fold_in key; hits bypass admission and are **bitwise identical** to
the cold path because content-derived default keys make identical
pixels take identical RNG paths.  Concurrent identical requests
coalesce onto one execution (dedup-in-flight) — straggler/retry
accounting stays per-underlying-execution.  Tier 2 is approximate by
construction (near-duplicate GAP embeddings, cosine-thresholded) and
only fires for images *headed into escalation*: a hit substitutes the
near-duplicate's FULL cached payload (message_bits, ok, n_corrected,
logits — the image's own round-0 decode is discarded) in place of
running the escalation rounds.  The round-0 decode itself always
executes (it produces the probe embedding), and images that settle at
round 0 are never touched by this tier.

Correctness anchor: results are **bit-identical** to
``DetectionPipeline.detect_batch`` of the same images with the same
keys, for any arrival order, coalescing, bucket size, or lane config —
each request carries its own fold_in key, per-image keys are derived
per *request* (not per coalesced batch) by the shared
``StageRegistry.image_keys``, and padding rows are sliced off before
the scatter.

Beyond the paper: straggler speculative re-execution (the watchdog
re-submits micro-batches that exceed the ``StragglerMonitor`` timeout;
first completion wins) and live lane reallocation (Algorithm 1 re-run
on *measured* stage latencies, applied with ``LaneExecutor.reconfigure``
without dropping queued work).

Adaptive escalation online (``DetectionConfig.escalate_tiles > 1``):
when a micro-batch completes its single-tile round, only the FAILED
(or thin-margin) images across its requests are regrouped into an
**escalation micro-batch** — a round-r payload the same stage graph
ingests as tile r of each image's plan, adding the new soft bits onto
the carried accumulator — and re-submitted to the executor, round by
round, until every image settles or the tile budget is spent.
Escalation batches get the full straggler treatment (monitored,
speculatively re-executed, first completion wins); requests resolve
when their last escalating image settles, bit-identical to
``detect_batch`` of the same images/keys at the same config.
Escalation rate, per-image tiles, and batch counts are exported
through the metrics registry (``stats()``).
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocator, lanes as lanes_lib
from repro.core import scheduler as sched_lib
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.stages import _pad_pow2
from repro.serving import cache as cache_lib
from repro.serving.batcher import (AdmissionError, BatcherConfig,
                                   MicroBatcher, pad_to_bucket)
from repro.serving.metrics import MetricsRegistry

_RESULT_FIELDS = ("message_bits", "ok", "n_corrected", "logits")


class RequestHandle:
    """Future for one submitted request (n images).

    ``priority`` is the admission class the batcher resolved for this
    request (per-class latency metrics key off it).  ``_ckey`` is the
    content-cache key when the exact tier is on — the resolver uses it
    to populate the cache and fan results out to coalesced in-flight
    followers."""

    def __init__(self, rid: int, n: int, priority: str = "default"):
        self.rid = rid
        self.n = n
        self.priority = priority
        self._ckey: Optional[bytes] = None
        self.t_submit = time.perf_counter()
        self._ready = threading.Event()
        self._result: Optional[Dict[str, np.ndarray]] = None
        self._error: Optional[BaseException] = None
        self.t_done: Optional[float] = None
        self._cb_lock = threading.Lock()
        self._callbacks: List = []

    def done(self) -> bool:
        return self._ready.is_set()

    def result(self, timeout: Optional[float] = None
               ) -> Dict[str, np.ndarray]:
        if not self._ready.wait(timeout):
            raise TimeoutError(f"request {self.rid} not done after "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def add_done_callback(self, fn):
        """Register ``fn(handle)`` to run when the handle settles
        (resolve or reject) — immediately if it already has.  Each
        callback fires exactly once; exceptions it raises propagate to
        the settling thread (callbacks are the fleet router's re-route
        hook, so failures there must be loud, not swallowed)."""
        with self._cb_lock:
            if not self._ready.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    def _fire_callbacks(self):
        with self._cb_lock:
            cbs, self._callbacks = self._callbacks, []
        for fn in cbs:
            fn(self)

    def _resolve(self, result: Dict[str, np.ndarray]):
        self.t_done = time.perf_counter()
        self._result = result
        self._ready.set()
        self._fire_callbacks()

    def _reject(self, err: BaseException):
        self.t_done = time.perf_counter()
        self._error = err
        self._ready.set()
        self._fire_callbacks()

    @property
    def latency_s(self) -> Optional[float]:
        return (self.t_done - self.t_submit
                if self.t_done is not None else None)


class _SlotState:
    """Partial results for a request whose images are still escalating:
    round-1 rows are held here, escalated rows overwrite them as their
    rounds settle, and the request's handle resolves when the last
    pending image settles."""

    def __init__(self, slot, rows: Dict[str, np.ndarray], pending: int,
                 embeds: Optional[np.ndarray] = None):
        self.slot = slot
        self.rows = {f: np.asarray(v).copy() for f, v in rows.items()}
        self.tiles_used = np.ones(rows["ok"].shape[0], np.int32)
        self.pending = pending
        # round-0 GAP embeddings of this request's images — escalated
        # verdicts are inserted into the tier-2 cache under them
        self.embeds = embeds


@dataclasses.dataclass
class _EscGroup:
    """One escalation micro-batch: the still-failing images gathered
    across a completed batch's requests, entering plan-tile ``round``
    with their accumulated soft bits."""
    raw: np.ndarray                           # (n, H, W, 3) true rows
    keys: Any                                 # (n,) typed PRNG keys
    acc: np.ndarray                           # (n, n_bits) accumulated
    targets: List[Tuple[_SlotState, int]]     # (state, row) per image
    round: int                                # plan column this round


@dataclasses.dataclass
class _InFlight:
    mb: Any                     # MicroBatch (round 0) or None
    tid: int
    esc: Optional[_EscGroup] = None   # escalation round payload
    done: bool = False          # first completion wins (speculative)


class DetectionServer:
    """Request-level serving runtime over the shared stage registry."""

    def __init__(self, cfg: DetectionConfig, extractor_params, *,
                 batcher: Optional[BatcherConfig] = None,
                 lanes: Optional[Dict[str, int]] = None,
                 straggler_policy: Optional[
                     sched_lib.StragglerPolicy] = None,
                 watchdog_interval_s: float = 0.05,
                 realloc_every: int = 0,
                 device=None,
                 name: str = "detect-server"):
        # optional device pin: every jit dispatch this server makes
        # (key derivation, stage fns, warmup) runs under
        # jax.default_device(device), so N in-process replicas spread
        # over N forced CPU devices instead of piling onto device 0 —
        # the CI-scale fleet simulation discipline of
        # tests/sharded_check.py
        self._device = device
        self.pipe = DetectionPipeline(cfg, extractor_params)
        self.registry = self.pipe.stages
        self.cfg = cfg
        self.name = name
        self.metrics = MetricsRegistry()
        self.batcher = MicroBatcher(batcher or BatcherConfig())
        # content-addressed result cache (serving.cache).  Tier 1
        # (exact sha256) + dedup-in-flight switch on together: both key
        # off the same content digest and share the exactness contract.
        # Tier 2 (near-duplicate GAP embedding) is independent and
        # approximate — it only short-circuits escalation rounds.
        if getattr(cfg, "cache_exact", False):
            self._exact: Optional[cache_lib.ResultCache] = \
                cache_lib.ResultCache(getattr(cfg, "cache_capacity", 256))
            self._dedup = cache_lib.InFlightTable()
        else:
            self._exact = None
            self._dedup = cache_lib.InFlightTable()  # pop(None) no-ops
        self._embed_thr = getattr(cfg, "cache_embedding_threshold", 0.0)
        self._embed: Optional[cache_lib.EmbeddingCache] = (
            cache_lib.EmbeddingCache(
                getattr(cfg, "cache_embedding_capacity", 512),
                self._embed_thr)
            if self._embed_thr > 0 else None)
        self.mon = sched_lib.StragglerMonitor(
            straggler_policy or sched_lib.StragglerPolicy())
        self._lanes = dict(lanes or self.pipe.default_lanes())
        self._watchdog_interval = watchdog_interval_s
        self._realloc_every = realloc_every
        self._ex: Optional[lanes_lib.LaneExecutor] = None
        self._stop = threading.Event()
        self._threads: list = []
        self._lock = threading.Lock()
        self._mon_lock = threading.Lock()   # StragglerMonitor is not
        self._esc_lock = threading.Lock()   # escalation slot states
        # escalation groups cross threads through a queue: _on_done runs
        # on the executor's dispatcher thread, whose blocking submit on
        # a full first-stage queue would deadlock the whole server (the
        # dispatcher is what drains those queues) — a dedicated pump
        # thread does the blocking submit instead
        self._esc_q: "queue.Queue[_EscGroup]" = queue.Queue()
        self._inflight: Dict[int, _InFlight] = {}   # thread-safe itself
        self._req_seq = 0
        self._tid_seq = 0
        self._batches_done = 0
        self._last_realloc = 0
        # admitted vs finished request counts close the drain() race: a
        # micro-batch in the pump's hands (popped from the batcher, not
        # yet in _inflight) is invisible to both queues, but its
        # requests are admitted-and-unfinished
        self._admitted = 0
        self._finished = 0
        # EWMA of measured per-stage seconds/batch for live reallocation
        self._stage_s: Dict[str, float] = {}
        self._stage_b: float = 0.0

    def _dev_ctx(self):
        """Context manager pinning jit dispatch to this server's device
        (no-op when unpinned — the single-server default)."""
        return (jax.default_device(self._device)
                if self._device is not None else contextlib.nullcontext())

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "DetectionServer":
        # escalate_inline=False: the server escalates by re-submitting
        # round-r micro-batches through this same executor (straggler
        # coverage + lane concurrency) instead of looping on an rs lane
        stages = self.registry.build_stages(
            self._lanes, finish=self._finish_payload,
            depth=2 if self.cfg.interleave else 1, escalate_inline=False,
            emit_embed=self._embed is not None)
        for st in stages:
            st.fn = self._timed(st.name, st.fn)
        self._ex = lanes_lib.LaneExecutor(stages, name=self.name).start()
        pump = threading.Thread(target=self._pump_loop, daemon=True,
                                name=f"{self.name}/pump")
        dog = threading.Thread(target=self._watchdog_loop, daemon=True,
                               name=f"{self.name}/watchdog")
        esc = threading.Thread(target=self._esc_loop, daemon=True,
                               name=f"{self.name}/escalation")
        pump.start()
        dog.start()
        esc.start()
        self._threads += [pump, dog, esc]
        return self

    def warmup(self, sample_image: np.ndarray):
        """Pre-compile the staged stage fns for every pad-bucket shape
        the batcher can emit (up to ``max_batch``) — otherwise each
        bucket's first micro-batch pays cold-start jit inside a served
        request's latency.  With escalation enabled the pow2
        escalation-round shapes are warmed too (the round index is
        traced, so one compile per shape covers every round) — a cold
        escalation compile would otherwise land inside a live request's
        latency and trip the straggler watchdog.  Runs the registry fns
        directly, off the metrics path."""
        cfg = self.batcher.cfg
        reg = self.registry
        with self._dev_ctx():
            return self._warmup_body(cfg, reg, sample_image)

    def _warmup_body(self, cfg, reg, sample_image: np.ndarray):
        sizes = []
        if cfg.bucket > 0:
            b = cfg.bucket
            while b < cfg.max_batch:
                sizes.append(b)
                b += cfg.bucket
        else:
            b = 1
            while b < cfg.max_batch:
                sizes.append(b)
                b *= 2
        sizes.append(pad_to_bucket(
            np.repeat(sample_image[None], cfg.max_batch, 0),
            cfg.bucket)[0].shape[0])
        for b in sorted(set(sizes)):
            raw = np.repeat(sample_image[None], b, axis=0)
            keys = reg.image_keys(reg.base_key, b)
            x = reg.ingest_keyed(raw, keys)
            if self._embed is not None:
                # the served round-0 decode is the embed-emitting
                # variant — warm that graph, not just the plain one
                logits, _ = reg.decode_keyed_embed(x, keys)
            else:
                logits = reg.decode_keyed(x, keys)
            jax.block_until_ready(reg.rs_correct(reg.bits(logits))[0])
        if reg.policy.enabled:
            # escalation groups pow2-pad, so warm up to the next power
            # of two >= the largest round-0 shape (a non-pow2 bucket
            # can otherwise produce a never-warmed escalation shape)
            top = 1
            while top < max(sizes):
                top *= 2
            b = 1
            while b <= top:
                raw = np.repeat(sample_image[None], b, axis=0)
                keys = reg.image_keys(reg.base_key, b)
                logits = reg.decode_tiles(
                    reg.escalation_tiles(raw, keys, 1))
                jax.block_until_ready(
                    reg.rs_correct(reg.bits(logits))[0])
                b *= 2
        return sorted(set(sizes))

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every admitted request has been resolved (covers
        the batcher queue, batches in the pump's hands, and the
        executor — nothing can be admitted-and-unfinished in between)."""
        t_end = (time.perf_counter() + timeout
                 if timeout is not None else None)
        while True:
            with self._lock:
                idle = self._finished >= self._admitted
            if idle:
                return True
            if t_end is not None and time.perf_counter() > t_end:
                return False
            time.sleep(0.002)

    def close(self):
        """Graceful shutdown: stop admission, drain in-flight work,
        stop the loops, close the executor and the pipeline.  Requests
        that survive the drain timeout are rejected, never left with an
        unresolved future."""
        self.batcher.close()
        # an un-started server has no pump to finish admitted work —
        # draining would just burn the timeout before the flush below
        # rejects everything queued
        self.drain(timeout=30.0 if self._threads else 0.0)
        self._stop.set()
        if self._ex is not None:
            self._ex.drain(timeout=10.0)
            self._ex.close()   # rejects leftover tickets THROUGH their
            #                    callbacks -> _on_done rejects the slots
        for e in self.batcher.flush():   # never popped by the pump
            self._finish_requests([e.slot], error=RuntimeError(
                f"{self.name}: server closed before dispatch"))
        while True:      # escalation groups never picked up by the pump
            try:
                g = self._esc_q.get_nowait()
            except queue.Empty:
                break
            self._fail_states(g.targets, RuntimeError(
                f"{self.name}: server closed before escalation dispatch"))
        self.pipe.close()
        me = threading.current_thread()
        for t in self._threads:
            if t is not me:
                t.join(timeout=2.0)

    def kill(self, error: Optional[BaseException] = None):
        """Abrupt shutdown — the crash-simulation path the fleet tier's
        fault injection drives.  Unlike :meth:`close` nothing is
        drained: admission stops, the executor is closed out from under
        its in-flight tickets (each rejects THROUGH its callback, so
        every admitted request's handle settles), and queued-but-never-
        dispatched requests are rejected.  No handle is ever left
        unresolved — the router's re-execution discipline depends on
        rejection, not on timeouts."""
        err = error if error is not None else RuntimeError(
            f"{self.name}: killed")
        self.batcher.close()
        self._stop.set()
        if self._ex is not None:
            self._ex.close()   # in-flight tickets reject via _on_done
        for e in self.batcher.flush():
            self._finish_requests([e.slot], error=err)
        while True:
            try:
                g = self._esc_q.get_nowait()
            except queue.Empty:
                break
            self._fail_states(g.targets, err)
        self.pipe.close()
        me = threading.current_thread()
        for t in self._threads:
            if t is not me:
                t.join(timeout=2.0)

    def reconfigure(self, lanes: Dict[str, int]) -> Dict[str, int]:
        """Apply an explicit lane map to the running executor (the
        rolling-reconfigure path: the router drains this replica, calls
        this, and returns it to rotation).  ``reallocate()`` is the
        measured/Algorithm-1 variant; this one takes the map as given."""
        if self._ex is None:
            self._lanes = dict(lanes)
            return dict(lanes)
        applied = self._ex.reconfigure(dict(lanes))
        self._lanes = dict(applied)
        self.metrics.count("reconfigures")
        return applied

    def load(self) -> Dict[str, int]:
        """Backpressure surface for the fleet router's least-loaded
        spill-over and health polling: queued images, admitted-but-
        unfinished requests, and the batcher's current admission
        headroom (images the highest class could still admit)."""
        with self._lock:
            inflight = self._admitted - self._finished
        return {"queue_depth": self.batcher.depth(),
                "inflight_requests": int(inflight),
                "headroom": self.batcher.headroom()}

    def _finish_requests(self, slots, *, error: BaseException):
        n = 0
        for slot in slots:
            slot._reject(error)
            n += 1
            # dedup followers coalesced onto this execution must be
            # rejected too — exactly-once settlement, even on the
            # close()/executor-failure paths
            for f in self._dedup.pop(getattr(slot, "_ckey", None)):
                f._reject(error)
                n += 1
        self.metrics.count("requests_failed", n)
        with self._lock:
            self._finished += n

    # -- request path ---------------------------------------------------------
    def content_key(self, images: np.ndarray):
        """The content-derived request fold_in key ``submit`` uses when
        ``cache_exact`` is on and no explicit key is given — exposed so
        offline baselines (``detect_batch`` / ``run_batch``) can
        reproduce a served (or cached) result bit-for-bit."""
        images = np.asarray(images)
        if images.ndim == 3:
            images = images[None]
        return self.registry.content_key(
            cache_lib.fingerprint32(cache_lib.request_digest(images)))

    def submit(self, images: np.ndarray, *, key=None,
               block: bool = False,
               priority: Optional[str] = None) -> RequestHandle:
        """Admit one request (n images, one fold_in key).

        ``key`` defaults to the offline discipline —
        ``fold_in(key(cfg.seed), request_seq)`` — so a stream of online
        requests reproduces ``detect_batch`` called once per request on
        a fresh pipeline.  With ``cache_exact`` on the default flips to
        the *content-derived* key (``content_key``): identical pixels
        get identical keys, which is what makes an exact cache hit
        bitwise equal to the cold path (per-request sequence keys would
        make every resubmission a distinct computation by design).
        ``priority`` selects the batcher admission class (None = the
        highest configured class).  Raises :class:`AdmissionError` on
        backpressure (``block=True`` waits instead)."""
        images = np.asarray(images)
        if images.ndim == 3:           # single image -> group of one
            images = images[None]
        try:
            cls = self.batcher.resolve_class(priority)
        except AdmissionError:
            self.metrics.count("requests_rejected")
            raise
        with self._lock:
            rid = self._req_seq
            self._req_seq += 1
        n = images.shape[0]
        handle = RequestHandle(rid, n, priority=cls)
        if self._exact is not None and n:
            digest = cache_lib.request_digest(images)
            if key is None:
                key = self.registry.content_key(
                    cache_lib.fingerprint32(digest))
            ckey = cache_lib.result_key(key, digest)
            hit = self._exact.get(ckey)
            if hit is not None:
                # cache hits bypass admission entirely — no queue
                # round-trip, no depth-bound backpressure
                self.metrics.count("cache_hit_exact")
                self.metrics.count("requests_admitted")
                with self._lock:
                    self._admitted += 1
                self._settle(handle, hit, count_tiles=False)
                return handle
            if self._dedup.attach(ckey, handle):
                # follower: an identical request is already executing —
                # coalesce onto it, the resolver fans the result out
                self.metrics.count("dedup_coalesced")
                self.metrics.count("requests_admitted")
                with self._lock:
                    self._admitted += 1
                return handle
            self.metrics.count("cache_miss")
            handle._ckey = ckey
        if key is None:
            key = self.registry.batch_key(rid)
        # per-REQUEST image keys: coalescing can't change them, which is
        # what makes online results bit-identical to offline (derived
        # under the device pin so pinned replicas keep every buffer —
        # keys included — colocated on their own device)
        with self._dev_ctx():
            keys = self.registry.image_keys(key, n) if n else None
        try:
            self.batcher.submit(images, keys, handle,
                                priority=cls, block=block)
        except AdmissionError:
            self.metrics.count("requests_rejected")
            # a leader that never dispatched must release its in-flight
            # claim and reject any followers that raced in behind it
            nf = 0
            for f in self._dedup.pop(handle._ckey):
                f._reject(AdmissionError(
                    "coalesced leader rejected at admission"))
                nf += 1
            if nf:
                self.metrics.count("requests_failed", nf)
                with self._lock:
                    self._finished += nf
            raise
        with self._lock:
            self._admitted += 1
        self.metrics.count("requests_admitted")
        self.metrics.gauge("queue_depth", self.batcher.depth())
        return handle

    # -- internal: micro-batch dispatch ---------------------------------------
    def _payload(self, inf: _InFlight) -> dict:
        # a FRESH dict per dispatch: stage fns annotate the payload in
        # place, so a speculative retry must not share the original
        if inf.esc is not None:
            g = inf.esc
            # pow2-pad the escalation rows (bounded jit shapes); the
            # pad rows are inert — results sliced to len(targets)
            with self._dev_ctx():
                raw, _ = _pad_pow2(g.raw)
                keys, _ = _pad_pow2(g.keys)
                acc, _ = _pad_pow2(g.acc)
                return {"raw": raw, "keys": keys, "round": g.round,
                        "acc_logits": jnp.asarray(acc)}
        return {"raw": inf.mb.raw, "keys": inf.mb.keys}

    def _dispatch(self, inf: _InFlight, *, retry: bool = False):
        if retry:
            self.metrics.count("straggler_retries")
        else:
            with self._mon_lock:
                self.mon.start(inf.tid)
        self._ex.submit(self._payload(inf),
                        callback=lambda t, inf=inf: self._on_done(inf, t))

    def _pump_loop(self):
        while not self._stop.is_set():
            mb = self.batcher.next_batch(timeout=0.05)
            if mb is None:
                continue
            with self._lock:
                tid = self._tid_seq
                self._tid_seq += 1
                inf = _InFlight(mb=mb, tid=tid)
                self._inflight[tid] = inf
            self.metrics.observe("batch_occupancy", mb.occupancy)
            self.metrics.observe("batch_images", mb.true_b)
            self.metrics.gauge("queue_depth", self.batcher.depth())
            try:
                self._dispatch(inf)
            except RuntimeError as e:   # executor closed under us: the
                # batch must still resolve (reject), and the pump must
                # keep looping to fail any remaining queued batches
                with self._lock:
                    inf.done = True
                    self._inflight.pop(inf.tid, None)
                self._finish_requests([s for s, _, _ in mb.slots],
                                      error=e)

    def _finish_payload(self, p: dict) -> dict:
        """Stage-graph sink: device -> numpy on the rs lane."""
        out = {"message_bits": np.asarray(p["msg"]),
               "ok": np.asarray(p["ok"]),
               "n_corrected": np.asarray(p["ncorr"]),
               "logits": np.asarray(p["logits"])}
        if "embed" in p:         # round-0 GAP embeddings (tier-2 cache)
            out["embed"] = np.asarray(p["embed"])
        return out

    def _on_done(self, inf: _InFlight, ticket):
        """Executor callback (completion order): scatter to requests,
        or advance the escalation state machine for round-r batches."""
        with self._lock:
            if inf.done:          # a speculative duplicate lost the race
                return
            inf.done = True
            self._inflight.pop(inf.tid, None)
            self._batches_done += 1
        with self._mon_lock:
            self.mon.complete(inf.tid)
        err = ticket.exception(0)
        if err is not None:
            if inf.esc is not None:
                self._fail_states(inf.esc.targets, err)
            else:
                self._finish_requests([s for s, _, _ in inf.mb.slots],
                                      error=err)
            return
        res = ticket.result(0)
        if inf.esc is not None:
            with self._esc_lock:
                self._scatter_escalation(inf.esc, res)
            return
        with self._esc_lock:
            self._scatter_round0(inf.mb, res)
        self.metrics.observe("batch_latency_s",
                             time.perf_counter() - inf.mb.t_formed)

    def _settle(self, slot, result: Dict[str, np.ndarray], *,
                count_tiles: bool = True):
        """Resolve one handle and account for it (per-class latency,
        completion counters).  ``count_tiles=False`` for cache hits and
        dedup followers — they adopted a result, no tiles ran for
        them, so they must not skew the escalation telemetry."""
        slot._resolve(result)
        n = result["message_bits"].shape[0]
        self.metrics.count("requests_completed")
        self.metrics.count("images_completed", n)
        self.metrics.observe("request_latency_s", slot.latency_s)
        self.metrics.observe(f"request_latency_{slot.priority}_s",
                             slot.latency_s)
        tiles = result.get("tiles_used")
        if count_tiles and tiles is not None:
            # counted at resolution (not when escalation starts), so
            # escalation_rate = images_escalated / images_completed is
            # a true fraction of COMPLETED images even while rounds are
            # in flight or after escalation failures
            self.metrics.count("images_escalated",
                               int((tiles > 1).sum()))
            for t in tiles:
                self.metrics.observe("tiles_per_image", float(t))
        with self._lock:
            self._finished += 1

    def _resolve_request(self, slot, result: Dict[str, np.ndarray]):
        """Settle an *executed* request: populate the exact cache
        BEFORE releasing its in-flight claim (no window where a new
        identical request sees neither), then fan the result out to
        every coalesced follower."""
        ckey = getattr(slot, "_ckey", None)
        if ckey is not None:
            if self._exact is not None:
                self._exact.put(ckey, result)
            followers = self._dedup.pop(ckey)
        else:
            followers = ()
        self._settle(slot, result)
        for f in followers:
            self._settle(f, cache_lib.copy_result(result),
                         count_tiles=False)

    def _embed_tier(self, rows, need: np.ndarray, embeds: np.ndarray,
                    off: int):
        """Tier-2 near-duplicate cache over round-0 GAP embeddings.
        Images about to escalate adopt a cached settled verdict when
        their embedding clears the cosine threshold — the approximate
        tier only short-circuits escalation rounds, never the exact
        path.  Adoption is WHOLESALE: every result field
        (message_bits, ok, n_corrected, logits) is replaced by the
        cached near-duplicate's payload and the image's own round-0
        decode is discarded — the deliberate semantics of an
        approximate tier (mixing the probe's failed bits with a
        borrowed ok verdict would produce incoherent rows).
        Settled-ok images insert their verdicts for future near-dupes.
        Mutates ``need`` in place; returns rows (copied to writable
        arrays if any verdict was adopted)."""
        want = np.nonzero(need)[0]
        adopted = np.zeros(need.shape, bool)
        if want.size:
            rows = {f: np.array(rows[f]) for f in _RESULT_FIELDS}
        for i in want:
            hit = self._embed.get(embeds[off + int(i)])
            if hit is None:
                continue
            for f in _RESULT_FIELDS:
                rows[f][i] = hit[f]
            need[i] = False
            adopted[i] = True
            self.metrics.count("cache_hit_embed")
        ok = np.asarray(rows["ok"], bool)
        for i in np.nonzero(~need & ~adopted & ok)[0]:
            emb = embeds[off + int(i)]
            if self._embed.get(emb) is None:   # keep entries distinct
                self._embed.put(
                    emb, {f: np.asarray(rows[f][int(i)]).copy()
                          for f in _RESULT_FIELDS})
        return rows

    def _scatter_round0(self, mb, res: Dict[str, np.ndarray]):
        """Completed single-tile round: resolve settled requests, hold
        the rest in slot states and regroup their failed images into
        one escalation micro-batch."""
        policy = self.registry.policy
        embeds = res.get("embed")
        esc: List[Tuple[_SlotState, int, int]] = []   # (state, row, gidx)
        for slot, off, n in mb.slots:
            rows = {f: res[f][off: off + n] for f in _RESULT_FIELDS}
            if not policy.enabled:
                self._resolve_request(slot, rows)
                continue
            need = np.array(policy.wants_escalation(rows["ok"],
                                                    rows["logits"]))
            if self._embed is not None and embeds is not None:
                rows = self._embed_tier(rows, need, embeds, off)
            if not need.any():
                self._resolve_request(
                    slot, {**rows, "tiles_used": np.ones(n, np.int32)})
                continue
            state = _SlotState(slot, rows, pending=int(need.sum()),
                               embeds=(embeds[off: off + n].copy()
                                       if embeds is not None else None))
            esc.extend((state, int(i), off + int(i))
                       for i in np.nonzero(need)[0])
        if esc:
            gidx = np.asarray([g for _, _, g in esc])
            self._dispatch_escalation(_EscGroup(
                raw=np.asarray(mb.raw)[gidx],
                keys=mb.keys[gidx],
                acc=np.asarray(res["logits"], np.float32)[gidx],
                targets=[(s, r) for s, r, _ in esc],
                round=1))

    def _scatter_escalation(self, g: _EscGroup, res: Dict[str, np.ndarray]):
        """Completed escalation round: settle images whose RS now
        succeeds (or whose budget is spent), re-group the rest for the
        next round with their accumulated soft bits."""
        policy = self.registry.policy
        n = len(g.targets)
        rows = {f: np.asarray(res[f])[:n] for f in _RESULT_FIELDS}
        need = policy.wants_escalation(rows["ok"], rows["logits"])
        nxt: List[int] = []
        for i, (state, row) in enumerate(g.targets):
            for f in _RESULT_FIELDS:
                state.rows[f][row] = rows[f][i]
            state.tiles_used[row] = g.round + 1
            if need[i] and g.round + 1 < policy.max_tiles:
                nxt.append(i)
                continue
            state.pending -= 1
            if (self._embed is not None and state.embeds is not None
                    and bool(rows["ok"][i])):
                # an escalation-settled verdict is exactly what the
                # tier-2 cache is for: the expensive multi-round answer,
                # keyed by the image's round-0 embedding so a near-dupe
                # can skip the rounds entirely
                emb = state.embeds[row]
                if self._embed.get(emb) is None:
                    self._embed.put(
                        emb, {f: np.asarray(rows[f][i]).copy()
                              for f in _RESULT_FIELDS})
            if state.pending == 0:
                self._resolve_request(
                    state.slot,
                    {**state.rows, "tiles_used": state.tiles_used})
        if nxt:
            sel = np.asarray(nxt)
            self._dispatch_escalation(_EscGroup(
                raw=g.raw[sel], keys=g.keys[sel],
                acc=rows["logits"][sel],
                targets=[g.targets[i] for i in nxt],
                round=g.round + 1))

    def _dispatch_escalation(self, group: _EscGroup):
        """Hand the group to the escalation pump (never submit from
        here: callers run on the executor's dispatcher thread, and a
        blocking submit there wedges the server — the dispatcher is
        the only consumer of the completion queue)."""
        self.metrics.count("escalation_batches")
        self.metrics.observe("escalation_batch_images",
                             len(group.targets))
        self._esc_q.put(group)

    def _esc_loop(self):
        """Escalation pump: pops groups and does the (possibly
        blocking) executor submit off the dispatcher thread."""
        while not self._stop.is_set():
            try:
                group = self._esc_q.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._lock:
                tid = self._tid_seq
                self._tid_seq += 1
                inf = _InFlight(mb=None, tid=tid, esc=group)
                self._inflight[tid] = inf
            try:
                self._dispatch(inf)
            except RuntimeError as e:   # executor closed under us
                with self._lock:
                    inf.done = True
                    self._inflight.pop(tid, None)
                self._fail_states(group.targets, e)

    def _fail_states(self, targets, err: BaseException):
        """Reject every request behind an escalation group that can no
        longer complete (a request's escalating rows always travel in
        one group, so each state appears in exactly one group)."""
        seen: Dict[int, _SlotState] = {}
        for state, _ in targets:
            seen.setdefault(id(state), state)
        n = 0
        for state in seen.values():
            state.slot._reject(err)
            n += 1
            for f in self._dedup.pop(getattr(state.slot, "_ckey", None)):
                f._reject(err)
                n += 1
        self.metrics.count("requests_failed", n)
        with self._lock:
            self._finished += n

    # -- straggler mitigation ----------------------------------------
    def _watchdog_loop(self):
        """Speculative re-execution: re-submit micro-batches the monitor
        flags as stragglers (stage fns are pure, first completion wins —
        ``_on_done`` drops the loser by the ``done`` flag).  Periodic
        live reallocation also runs here: reconfigure() can block on the
        bounded stage queues, which must never happen on the executor's
        dispatcher thread (it is what drains them)."""
        while not self._stop.is_set():
            time.sleep(self._watchdog_interval)
            with self._mon_lock:
                stragglers = self.mon.stragglers()
            for tid in stragglers:
                with self._lock:
                    inf = self._inflight.get(tid)
                if inf is None or inf.done:
                    continue
                with self._mon_lock:
                    self.mon.mark_retried(tid)
                try:
                    self._dispatch(inf, retry=True)
                except RuntimeError:
                    return        # executor closed under us
            if self._realloc_every:
                with self._lock:
                    due = (self._batches_done - self._last_realloc
                           >= self._realloc_every)
                    if due:
                        self._last_realloc = self._batches_done
                if due:
                    try:
                        self.reallocate()
                    except Exception:
                        pass      # reallocation must never kill serving

    # -- live reallocation -------------------------------------------
    def _timed(self, name: str, fn):
        def timed_fn(p):
            t0 = time.perf_counter()
            with self._dev_ctx():
                out = fn(p)
            dt = time.perf_counter() - t0
            if p.get("round", 0) > 0:
                # escalation rounds are tiny pow2 sub-batches: feeding
                # them into the EWMA would skew the Algorithm-1 profiles
                # (and _stage_b) toward a workload the allocator should
                # not tune for — tracked separately instead
                self.metrics.observe(f"stage_{name}_esc_s", dt)
                return out
            with self._lock:
                prev = self._stage_s.get(name)
                self._stage_s[name] = (dt if prev is None
                                       else 0.8 * prev + 0.2 * dt)
                if name == "ingest":
                    b = p["raw"].shape[0]
                    self._stage_b = (b if not self._stage_b
                                     else 0.8 * self._stage_b + 0.2 * b)
            self.metrics.observe(f"stage_{name}_s", dt)
            return out
        return timed_fn

    def stage_profiles(self):
        """Algorithm 1 profiles from the *measured* (EWMA) stage wall
        times — the online replacement for warmup profiling.  Jitted
        stage fns dispatch asynchronously, so these are dispatch+host
        times; they still rank the stages, which is what the allocator
        consumes.  Returns None until every stage has been observed."""
        with self._lock:
            if any(n not in self._stage_s for n in ("ingest", "decode",
                                                    "rs")):
                return None
            b = max(self._stage_b, 1.0)
            # u is not measurable from wall times; 1 byte/sample keeps
            # the allocation latency-driven (the warmup path measures
            # real bytes when a memory cap matters)
            return [allocator.StageProfile(
                        name=n, t_per_sample=self._stage_s[n] / b,
                        u_per_sample=1.0, launch_overhead=0.0)
                    for n in ("ingest", "decode", "rs")]

    def reallocate(self, lane_budget: Optional[int] = None
                   ) -> Optional[Dict[str, int]]:
        """Re-run Algorithm 1 on measured stage latencies and apply the
        allocation to the RUNNING executor (live reconfiguration); the
        paper's warmup allocation assumed latencies that drift under
        real traffic.  No-op until all stages have been measured."""
        profiles = self.stage_profiles()
        if profiles is None or self._ex is None:
            return None
        budget = lane_budget or self.cfg.lane_budget
        new = allocator.assign(
            profiles, global_batch=max(int(self._stage_b), 1),
            lane_budget=budget)
        self._lanes = new
        applied = self._ex.reconfigure(new)
        self.metrics.count("reallocations")
        return applied

    # -- reporting ------------------------------------------------------------
    def lane_counts(self) -> Dict[str, int]:
        return (self._ex.lane_counts() if self._ex is not None
                else dict(self._lanes))

    def stats(self) -> dict:
        out = self.metrics.snapshot()
        out["lanes"] = self.lane_counts()
        # the resettable metrics counter, NOT mon.retry_count: one
        # server is reused across fig11 sweep points with a metrics
        # reset between them, and the monitor's cumulative total would
        # misattribute earlier points' retries to later rows
        out["straggler_retries"] = int(
            self.metrics.counter("straggler_retries"))
        out["queue_depth"] = self.batcher.depth()
        # escalation rate: fraction of completed images that needed
        # more than their single-tile round (0.0 when escalation off)
        done = self.metrics.counter("images_completed")
        out["escalation_rate"] = (
            self.metrics.counter("images_escalated") / done
            if done else 0.0)
        out["escalation_batches"] = int(
            self.metrics.counter("escalation_batches"))
        # cache / dedup funnel (rates are derived in snapshot())
        for c in ("cache_hit_exact", "cache_hit_embed", "cache_miss",
                  "dedup_coalesced"):
            out[c] = int(self.metrics.counter(c))
        out["class_depths"] = self.batcher.class_depths()
        return out
