"""Content-addressed result caching for the online serving tier.

At production traffic the same image reaches the detector many times —
re-uploads, thumbnails, CDN re-encodes — and every duplicate pays the
full ingest→decode→RS pipeline for a verdict that is deterministic per
(image, key).  This module gives :class:`~repro.serving.DetectionServer`
three ways to avoid that recompute:

* **tier 1 — exact** (:class:`ResultCache`): a host-side
  *cryptographic* content digest (sha256 over the image shape and the
  canonical float64 pixel bytes, computed in the submit path before
  admission) keys an LRU of full request results.  Hits bypass
  admission, the batcher, and the executor entirely.  Exactness
  contract: the digest binds every pixel value bit-for-bit (distinct
  images cannot collide — a perceptual hash would violate this for
  e.g. flat/low-texture images), the cache key includes the request's
  fold_in key material, and when the caller passes no key the server
  derives one *from the content digest* — so identical pixels map to
  identical keys and a hit is bitwise what the cold path would produce;
* **dedup-in-flight** (:class:`InFlightTable`): concurrent identical
  requests coalesce onto the first one's execution; the followers'
  handles fan out from the leader's resolution (or rejection — a
  follower is never left hanging).  Straggler/retry accounting stays
  per-underlying-execution because followers never reach the executor;
* **tier 2 — near-duplicate** (:class:`EmbeddingCache`): the
  extractor's own GAP embedding (a free byproduct of the fused decode
  kernel) keys a small LRU of settled per-image verdicts under a
  cosine threshold.  This tier is an explicit *approximation* — a hit
  substitutes the near-duplicate's FULL cached payload (message_bits,
  ok, n_corrected, logits; the probe image's own round-0 decode is
  discarded for that image), not a bitwise recompute — so it only
  short-circuits the expensive escalation path, never the single-tile
  fast path, and the threshold defaults conservative
  (``DetectionConfig.cache_embedding_threshold``).

The perceptual hashes (:func:`dhash` / :func:`ahash`) are retained as
*approximate* similarity utilities only — they are deliberately lossy
(64 bits from block means) and MUST NOT key any tier that promises
exactness; the exact tier and the in-flight table key on
:func:`image_digest`'s sha256.

Everything here is plain numpy + locks: hashing must stay off the
device (it runs before admission, on the submit thread) and the caches
are shared across the server's pump/dispatcher/escalation threads.
"""
from __future__ import annotations

import hashlib
import threading
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional

import numpy as np

# luma weights (BT.601) — the plane both perceptual hashes see
_LUMA = np.asarray([0.299, 0.587, 0.114], np.float64)
# perceptual-hash grid side: 8 -> 64-bit dHash + 64-bit aHash
_PHASH_SIDE = 8


def _resize_mean(x: np.ndarray, oh: int, ow: int) -> np.ndarray:
    """Block-mean (area-average) resize of a 2-D plane via an integral
    image — exact in float64, so the hash is a pure function of pixel
    values (no interpolation-library dependence).  The output grid is
    clamped to the input shape: an image smaller than the requested
    grid yields fewer cells rather than zero-area blocks (which would
    divide by zero and poison the hash bits with NaN)."""
    h, w = x.shape
    oh, ow = min(oh, h), min(ow, w)
    ys = (np.arange(oh + 1) * h) // oh
    xs = (np.arange(ow + 1) * w) // ow
    c = np.zeros((h + 1, w + 1), np.float64)
    np.cumsum(np.cumsum(x, axis=0), axis=1, out=c[1:, 1:])
    out = (c[ys[1:, None], xs[None, 1:]] - c[ys[:-1, None], xs[None, 1:]]
           - c[ys[1:, None], xs[None, :-1]]
           + c[ys[:-1, None], xs[None, :-1]])
    area = (ys[1:, None] - ys[:-1, None]) * (xs[1:] - xs[:-1])[None, :]
    return out / area


def _luma(img: np.ndarray) -> np.ndarray:
    """(H, W, 3) raw image (uint8 or float in the 0..255 domain) ->
    float64 luma plane.  uint8 -> float64 is exact, so a no-op
    re-encode (uint8 -> float -> uint8) cannot move the hash."""
    return np.asarray(img, np.float64) @ _LUMA


def _pack_bits(bits: np.ndarray) -> int:
    return int.from_bytes(np.packbits(bits.ravel()).tobytes(), "big")


def dhash(img: np.ndarray, side: int = _PHASH_SIDE) -> int:
    """Difference hash: sign of horizontal gradient on the (side,
    side+1) block-mean luma plane -> up to side*side bits (fewer for
    images smaller than the grid).  APPROXIMATE — similarity utility
    only, never an exactness key."""
    p = _resize_mean(_luma(img), side, side + 1)
    return _pack_bits(p[:, 1:] > p[:, :-1])


def ahash(img: np.ndarray, side: int = _PHASH_SIDE) -> int:
    """Average hash: per-cell mean vs global mean on the (side, side)
    block-mean luma plane -> up to side*side bits.  APPROXIMATE —
    similarity utility only, never an exactness key."""
    p = _resize_mean(_luma(img), side, side)
    return _pack_bits(p > p.mean())


def image_digest(img: np.ndarray) -> bytes:
    """The tier-1 per-image content digest: sha256 over shape + the
    canonical float64 pixel bytes.  Cryptographic — distinct images
    cannot collide, which the exact tier's "bitwise identical to the
    cold path" contract requires (a perceptual hash collides on e.g.
    flat/low-texture images).  Canonicalizing through float64 keeps
    the digest invariant under no-op re-encodes (uint8 -> float ->
    uint8 is exact in float64), matching what the ingest stage sees."""
    a = np.ascontiguousarray(np.asarray(img, np.float64))
    h = hashlib.sha256()
    h.update(np.asarray(a.shape, np.int64).tobytes())
    h.update(a.tobytes())
    return h.digest()


def request_digest(images: np.ndarray) -> bytes:
    """Digest of a whole request (n images, order-sensitive — image i
    gets per-image key fold_in(request_key, i), so order matters to
    the result)."""
    return b"".join(image_digest(images[i])
                    for i in range(images.shape[0]))


def fingerprint32(digest: bytes) -> int:
    """Fold a digest to the 32-bit value ``fold_in`` consumes — the
    content-derived request key is fold_in(key(seed), fingerprint)."""
    return zlib.crc32(digest) & 0xFFFFFFFF


def result_key(key, digest: bytes) -> bytes:
    """The exact-tier cache key: the request's fold_in key material
    (so explicit-key traffic caches correctly too) + the content
    digest.  With content-derived keys the key part is redundant but
    harmless — it keeps the invariant "same cache key => same cold
    result" true for every caller."""
    import jax
    kd = np.asarray(jax.random.key_data(key), np.uint32)
    return kd.tobytes() + digest


def copy_result(result: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Deep-copy a result dict so cache hits / dedup fan-outs can never
    alias a buffer another handle's owner may mutate."""
    return {f: np.array(v, copy=True) for f, v in result.items()}


class ResultCache:
    """Tier 1: thread-safe LRU of full request results keyed by
    ``result_key``.  get/put both copy — the cache owns its arrays."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._d: "OrderedDict[bytes, Dict[str, np.ndarray]]" = OrderedDict()

    def get(self, key: bytes) -> Optional[Dict[str, np.ndarray]]:
        with self._lock:
            hit = self._d.get(key)
            if hit is None:
                return None
            self._d.move_to_end(key)
            return copy_result(hit)

    def put(self, key: bytes, result: Dict[str, np.ndarray]):
        with self._lock:
            self._d[key] = copy_result(result)
            self._d.move_to_end(key)
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)


class EmbeddingCache:
    """Tier 2: near-duplicate matching on the extractor's normalized
    GAP embedding under a cosine threshold.

    Entries are per-IMAGE settled verdicts.  A lookup normalizes the
    probe, takes the best cosine over the (bounded) entry matrix, and
    returns a copy of the matched rows iff cosine >= threshold.
    Approximate by construction — callers must only use it where a
    near-duplicate verdict is an acceptable answer (the server limits
    it to short-circuiting escalation rounds)."""

    def __init__(self, capacity: int = 512, threshold: float = 0.995):
        if capacity < 1:
            raise ValueError("embedding cache capacity must be >= 1")
        if not 0.0 < threshold < 1.0 + 1e-9:
            raise ValueError("cosine threshold must be in (0, 1]")
        self.capacity = capacity
        self.threshold = float(threshold)
        self._lock = threading.Lock()
        self._vecs: List[np.ndarray] = []     # unit-norm float64
        self._rows: List[Dict[str, np.ndarray]] = []

    @staticmethod
    def _unit(vec: np.ndarray) -> Optional[np.ndarray]:
        v = np.asarray(vec, np.float64).ravel()
        n = np.linalg.norm(v)
        if not np.isfinite(n) or n == 0.0:
            return None
        return v / n

    def get(self, vec: np.ndarray) -> Optional[Dict[str, np.ndarray]]:
        v = self._unit(vec)
        if v is None:
            return None
        with self._lock:
            if not self._vecs:
                return None
            sims = np.stack(self._vecs) @ v
            best = int(np.argmax(sims))
            if sims[best] < self.threshold:
                return None
            return copy_result(self._rows[best])

    def put(self, vec: np.ndarray, rows: Dict[str, np.ndarray]):
        v = self._unit(vec)
        if v is None:
            return
        with self._lock:
            self._vecs.append(v)
            self._rows.append(copy_result(rows))
            while len(self._vecs) > self.capacity:
                self._vecs.pop(0)
                self._rows.pop(0)

    def __len__(self) -> int:
        with self._lock:
            return len(self._vecs)


class InFlightTable:
    """Dedup-in-flight: the first submitter of a cache key is the
    *leader* (it runs the pipeline); identical keys arriving while the
    leader is unresolved *attach* as followers and are settled by the
    leader's resolution/rejection fan-out.

    Race discipline (all windows close to at-most-harmless):

    * ``attach`` atomically either registers the caller as leader
      (returns None) or appends its handle to the existing entry
      (returns the leader-owned entry marker, truthy);
    * the resolver inserts into the exact cache *before* popping the
      entry, so a request arriving in between sees either the entry
      (follower) or the cache (hit) — never neither;
    * two leaders for the same key (entry popped between one's miss
      and the other's attach) just means one harmless double-compute
      of a deterministic result.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._waiters: Dict[bytes, List] = {}

    def attach(self, key: bytes, handle) -> bool:
        """True -> attached as follower; False -> caller is now the
        leader for ``key`` and must eventually ``pop`` it."""
        with self._lock:
            w = self._waiters.get(key)
            if w is None:
                self._waiters[key] = []
                return False
            w.append(handle)
            return True

    def pop(self, key: Optional[bytes]) -> List:
        """Remove ``key``'s entry and return its followers (empty when
        ``key`` is None or unknown).  Exactly-once: each follower
        handle appears in exactly one pop."""
        if key is None:
            return []
        with self._lock:
            return self._waiters.pop(key, [])

    def depth(self) -> int:
        with self._lock:
            return sum(len(w) for w in self._waiters.values())
