"""Dynamic micro-batching for the online detection server.

Requests (single images or small groups, each with pre-derived
per-image fold_in keys) arrive over time; the batcher coalesces queued
requests into ``pad_to_bucket``-shaped micro-batches under a
``max_wait_ms`` deadline:

* a micro-batch ships as soon as ``max_batch`` images are queued, or
  when the *oldest* queued request has waited ``max_wait_ms`` —
  deadline-triggered partial batches keep tail latency bounded at low
  offered load, batch shaping keeps throughput at high load;
* request groups are atomic (one request's images never split across
  micro-batches), so each request's result rows are one contiguous
  slice;
* admission control is depth-bounded: when ``max_queue`` images are
  already waiting, ``submit`` raises :class:`AdmissionError`
  (backpressure to the client, not host OOM) unless ``block=True``.

SLO-tiered admission (``BatcherConfig.classes``): requests may carry a
priority class, each class with its own deadline generalizing
``max_wait_ms``.  Dict order is priority order — when a micro-batch
forms, higher classes are popped first and lower classes only backfill
the remaining capacity (interactive preempts bulk), while the shipping
deadline is the earliest across class heads so no class's SLO is
hostage to another's.  Aging closes the starvation hole priority
popping would otherwise open: an entry whose deadline has already
expired is promoted to the head of the pop order (earliest expired
deadline first, ahead of fresh higher-class traffic), so even when
interactive load alone fills ``max_batch`` every cycle, a bulk entry
waits at most ~its deadline before it is *included* in a batch — the
deadline bounds inclusion, not just ship timing.  Backpressure is
tiered too: classes after the
first admit only up to ``bulk_admit_frac * max_queue`` queued images,
so bulk traffic absorbs ``AdmissionError`` first and the interactive
class keeps headroom.  With ``classes=None`` (default) everything runs
as one class with ``max_wait_ms`` — bit-for-bit the legacy behavior.

Bit-identity: the batcher only moves arrays around — keys travel with
their images, padding rows repeat the last image/key and are sliced
off after RS — so any coalescing of any arrival order produces results
bitwise equal to ``detect_batch`` of each request alone with its key.
Priority classes reorder *which* requests coalesce together, which the
per-request key discipline makes result-inert.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np


class AdmissionError(RuntimeError):
    """Request rejected at admission (invalid, or queue depth bound)."""


def pad_to_bucket(raw: np.ndarray, bucket: int = 0) -> Tuple[np.ndarray, int]:
    """Pad a ragged batch up to a shape bucket: the next power of two
    when ``bucket`` is 0, else the next multiple of ``bucket``.  Returns
    (padded batch, true size).  Bounded bucket count = bounded number of
    jit compilations no matter what sizes clients send.  Empty batches
    are rejected — there is no row to repeat and no work to do."""
    b = raw.shape[0]
    if b == 0:
        raise AdmissionError(
            "pad_to_bucket: empty batch (b == 0) — reject empty "
            "requests at admission instead of padding nothing")
    if bucket > 0:
        target = -(-b // bucket) * bucket
    else:
        target = 1
        while target < b:
            target *= 2
    if target == b:
        return raw, b
    return np.concatenate(
        [raw, np.repeat(raw[-1:], target - b, axis=0)]), b


@dataclasses.dataclass
class BatcherConfig:
    max_batch: int = 32       # images per coalesced micro-batch
    max_wait_ms: float = 5.0  # oldest-request deadline for partial ships
    max_queue: int = 256      # queued-image admission bound
    bucket: int = 0           # pad_to_bucket granularity (0 = pow2)
    # SLO classes: {name: max_wait_ms}, dict order = priority order
    # (first = highest).  None = single legacy class ("default",
    # max_wait_ms).  Non-first classes admit only up to
    # bulk_admit_frac * max_queue queued images.
    classes: Optional[Mapping[str, float]] = None
    bulk_admit_frac: float = 0.5


@dataclasses.dataclass
class _Entry:
    images: np.ndarray        # (n, H, W, 3) uint8
    keys: Any                 # (n,) typed PRNG keys (jax array)
    slot: Any                 # opaque per-request handle for the scatter
    t_enq: float


@dataclasses.dataclass
class MicroBatch:
    """One coalesced, padded unit of work for the stage graph."""
    raw: np.ndarray           # (padded_b, H, W, 3)
    keys: Any                 # (padded_b,) typed PRNG keys
    slots: List[Tuple[Any, int, int]]   # (slot, offset, n) per request
    true_b: int
    padded_b: int
    t_formed: float

    @property
    def occupancy(self) -> float:
        return self.true_b / self.padded_b if self.padded_b else 0.0


class MicroBatcher:
    """Thread-safe request queue + deadline-driven coalescer."""

    def __init__(self, cfg: BatcherConfig = BatcherConfig()):
        if cfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if cfg.classes is not None and not cfg.classes:
            raise ValueError("classes must be a non-empty mapping "
                             "(or None for the single legacy class)")
        if not 0.0 < cfg.bulk_admit_frac <= 1.0:
            raise ValueError("bulk_admit_frac must be in (0, 1]")
        self.cfg = cfg
        # priority order = dict order; single legacy class otherwise
        if cfg.classes:
            self.classes = list(cfg.classes)
            self._wait_ms = {c: float(cfg.classes[c])
                             for c in self.classes}
        else:
            self.classes = ["default"]
            self._wait_ms = {"default": cfg.max_wait_ms}
        for c, w in self._wait_ms.items():
            if w <= 0:
                raise ValueError(f"class {c!r} deadline must be > 0 ms")
        self._cv = threading.Condition()
        self._q: Dict[str, List[_Entry]] = {c: [] for c in self.classes}
        self._depth = 0           # queued images, all classes
        self._closed = False

    # -- admission --------------------------------------------------------
    def resolve_class(self, priority: Optional[str] = None) -> str:
        """Map a request's priority to a configured class (None -> the
        highest class).  Unknown names are an admission error — a
        client bug, surfaced where every other invalid request is."""
        if priority is None:
            return self.classes[0]
        if priority not in self._wait_ms:
            raise AdmissionError(
                f"unknown priority class {priority!r} "
                f"(configured: {self.classes})")
        return priority

    def _admit_bound(self, cls: str) -> int:
        """Per-class queued-image bound: the highest class gets the
        full ``max_queue``; every lower class only
        ``bulk_admit_frac * max_queue`` — bulk traffic hits
        backpressure first and interactive keeps headroom."""
        if cls == self.classes[0]:
            return self.cfg.max_queue
        return max(1, int(self.cfg.max_queue * self.cfg.bulk_admit_frac))

    def submit(self, images: np.ndarray, keys, slot,
               *, priority: Optional[str] = None,
               block: bool = False, timeout: Optional[float] = None):
        """Admit one request.  Raises :class:`AdmissionError` on an
        empty/oversized request or (``block=False``) a full queue."""
        n = int(images.shape[0])
        if n == 0:
            raise AdmissionError("empty request (0 images)")
        if n > self.cfg.max_batch:
            raise AdmissionError(
                f"request of {n} images exceeds max_batch="
                f"{self.cfg.max_batch}; split it client-side")
        cls = self.resolve_class(priority)
        bound = self._admit_bound(cls)
        with self._cv:
            if self._closed:
                raise AdmissionError("batcher closed")
            if self._depth + n > bound:
                if not block:
                    raise AdmissionError(
                        f"queue full ({self._depth}/{bound} images "
                        f"queued for class {cls!r}) — backpressure, "
                        f"retry later")
                ok = self._cv.wait_for(
                    lambda: self._closed
                    or self._depth + n <= bound, timeout)
                if not ok or self._closed:
                    raise AdmissionError("queue full (timed out blocking)"
                                         if not self._closed else
                                         "batcher closed")
            self._q[cls].append(
                _Entry(images, keys, slot, time.perf_counter()))
            self._depth += n
            self._cv.notify_all()

    def depth(self) -> int:
        """Queued images (admission-control view of the backlog)."""
        with self._cv:
            return self._depth

    def headroom(self, priority: Optional[str] = None) -> int:
        """Images a non-blocking :meth:`submit` for this class could
        admit right now (0 when closed or at the class's depth bound) —
        the backpressure surface the fleet router's least-loaded
        spill-over reads instead of probing with doomed submits."""
        cls = self.resolve_class(priority)
        with self._cv:
            if self._closed:
                return 0
            return max(0, self._admit_bound(cls) - self._depth)

    def class_depths(self) -> Dict[str, int]:
        """Queued images per priority class (metrics view)."""
        with self._cv:
            return {c: sum(e.images.shape[0] for e in q)
                    for c, q in self._q.items()}

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def flush(self) -> List[_Entry]:
        """Drain and return whatever is still queued — the shutdown
        path, so a forced close can reject the orphaned requests
        instead of leaving their futures unresolved."""
        with self._cv:
            take: List[_Entry] = []
            for c in self.classes:
                take.extend(self._q[c])
                self._q[c] = []
            self._depth = 0
            self._cv.notify_all()
            return take

    # -- coalescing ---------------------------------------------------------
    def _earliest_deadline(self) -> float:
        """Min over class heads of (enqueue time + class deadline) —
        the partial-batch ship time.  Caller holds the lock and
        guarantees at least one queue is non-empty."""
        return min(q[0].t_enq + self._wait_ms[c] / 1e3
                   for c, q in self._q.items() if q)

    def next_batch(self, timeout: Optional[float] = None
                   ) -> Optional[MicroBatch]:
        """Block until a micro-batch is ready (or ``timeout``); returns
        None on timeout or when closed and empty.

        Ships when ``max_batch`` images are queued or the earliest
        per-class head deadline expires — whichever first.  Popping is
        in priority order — the highest class fills first, lower
        classes backfill remaining capacity — EXCEPT that entries whose
        deadline has already expired are promoted ahead of everything
        (earliest expired deadline first), so sustained high-class
        traffic can delay a lower class only up to its deadline, never
        starve it out of batches entirely."""
        cfg = self.cfg
        with self._cv:
            if not self._cv.wait_for(
                    lambda: self._depth or self._closed, timeout):
                return None
            if not self._depth:
                return None          # closed and empty
            while (not self._closed and self._depth < cfg.max_batch):
                # recomputed every wake: a late higher-priority arrival
                # with a shorter deadline must be able to pull the ship
                # time earlier
                rem = self._earliest_deadline() - time.perf_counter()
                if rem <= 0:
                    break
                self._cv.wait(rem)
                if not self._depth:  # drained by close() race
                    return None
            # pop whole requests up to max_batch (groups stay atomic):
            # heads whose deadline already expired go first (earliest
            # expired deadline wins, regardless of class — the aging
            # rule that keeps bulk from starving under an interactive
            # flood), then priority order, lower classes backfilling
            take: List[_Entry] = []
            total = 0
            now = time.perf_counter()
            while True:
                best = None       # (sort key, class)
                for i, c in enumerate(self.classes):
                    q = self._q[c]
                    if not q or total + q[0].images.shape[0] \
                            > cfg.max_batch:
                        continue
                    dl = q[0].t_enq + self._wait_ms[c] / 1e3
                    # expired heads (0, deadline, ...) sort before all
                    # fresh heads (1, priority, ...)
                    k = (0, dl, i) if dl <= now else (1, i, 0.0)
                    if best is None or k < best[0]:
                        best = (k, c)
                if best is None:
                    break
                e = self._q[best[1]].pop(0)
                take.append(e)
                total += e.images.shape[0]
            self._depth -= total
            self._cv.notify_all()    # wake blocked submitters
        assert take, "next_batch woke with an un-poppable queue head"
        raw = (take[0].images if len(take) == 1
               else np.concatenate([e.images for e in take]))
        keys = (take[0].keys if len(take) == 1
                else jnp.concatenate([e.keys for e in take]))
        raw, true_b = pad_to_bucket(raw, cfg.bucket)
        pad = raw.shape[0] - true_b
        if pad:
            # pad keys like the images: repeated rows are inert (results
            # sliced off before the scatter), any key value works
            keys = jnp.concatenate([keys, jnp.repeat(keys[-1:], pad,
                                                     axis=0)])
        slots, off = [], 0
        for e in take:
            n = e.images.shape[0]
            slots.append((e.slot, off, n))
            off += n
        return MicroBatch(raw=raw, keys=keys, slots=slots, true_b=true_b,
                          padded_b=raw.shape[0],
                          t_formed=time.perf_counter())
