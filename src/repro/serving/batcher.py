"""Dynamic micro-batching for the online detection server.

Requests (single images or small groups, each with pre-derived
per-image fold_in keys) arrive over time; the batcher coalesces queued
requests into ``pad_to_bucket``-shaped micro-batches under a
``max_wait_ms`` deadline:

* a micro-batch ships as soon as ``max_batch`` images are queued, or
  when the *oldest* queued request has waited ``max_wait_ms`` —
  deadline-triggered partial batches keep tail latency bounded at low
  offered load, batch shaping keeps throughput at high load;
* request groups are atomic (one request's images never split across
  micro-batches), so each request's result rows are one contiguous
  slice;
* admission control is depth-bounded: when ``max_queue`` images are
  already waiting, ``submit`` raises :class:`AdmissionError`
  (backpressure to the client, not host OOM) unless ``block=True``.

Bit-identity: the batcher only moves arrays around — keys travel with
their images, padding rows repeat the last image/key and are sliced
off after RS — so any coalescing of any arrival order produces results
bitwise equal to ``detect_batch`` of each request alone with its key.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np


class AdmissionError(RuntimeError):
    """Request rejected at admission (invalid, or queue depth bound)."""


def pad_to_bucket(raw: np.ndarray, bucket: int = 0) -> Tuple[np.ndarray, int]:
    """Pad a ragged batch up to a shape bucket: the next power of two
    when ``bucket`` is 0, else the next multiple of ``bucket``.  Returns
    (padded batch, true size).  Bounded bucket count = bounded number of
    jit compilations no matter what sizes clients send.  Empty batches
    are rejected — there is no row to repeat and no work to do."""
    b = raw.shape[0]
    if b == 0:
        raise AdmissionError(
            "pad_to_bucket: empty batch (b == 0) — reject empty "
            "requests at admission instead of padding nothing")
    if bucket > 0:
        target = -(-b // bucket) * bucket
    else:
        target = 1
        while target < b:
            target *= 2
    if target == b:
        return raw, b
    return np.concatenate(
        [raw, np.repeat(raw[-1:], target - b, axis=0)]), b


@dataclasses.dataclass
class BatcherConfig:
    max_batch: int = 32       # images per coalesced micro-batch
    max_wait_ms: float = 5.0  # oldest-request deadline for partial ships
    max_queue: int = 256      # queued-image admission bound
    bucket: int = 0           # pad_to_bucket granularity (0 = pow2)


@dataclasses.dataclass
class _Entry:
    images: np.ndarray        # (n, H, W, 3) uint8
    keys: Any                 # (n,) typed PRNG keys (jax array)
    slot: Any                 # opaque per-request handle for the scatter
    t_enq: float


@dataclasses.dataclass
class MicroBatch:
    """One coalesced, padded unit of work for the stage graph."""
    raw: np.ndarray           # (padded_b, H, W, 3)
    keys: Any                 # (padded_b,) typed PRNG keys
    slots: List[Tuple[Any, int, int]]   # (slot, offset, n) per request
    true_b: int
    padded_b: int
    t_formed: float

    @property
    def occupancy(self) -> float:
        return self.true_b / self.padded_b if self.padded_b else 0.0


class MicroBatcher:
    """Thread-safe request queue + deadline-driven coalescer."""

    def __init__(self, cfg: BatcherConfig = BatcherConfig()):
        if cfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self._cv = threading.Condition()
        self._q: List[_Entry] = []
        self._depth = 0           # queued images
        self._closed = False

    # -- admission --------------------------------------------------------
    def submit(self, images: np.ndarray, keys, slot,
               *, block: bool = False, timeout: Optional[float] = None):
        """Admit one request.  Raises :class:`AdmissionError` on an
        empty/oversized request or (``block=False``) a full queue."""
        n = int(images.shape[0])
        if n == 0:
            raise AdmissionError("empty request (0 images)")
        if n > self.cfg.max_batch:
            raise AdmissionError(
                f"request of {n} images exceeds max_batch="
                f"{self.cfg.max_batch}; split it client-side")
        with self._cv:
            if self._closed:
                raise AdmissionError("batcher closed")
            if self._depth + n > self.cfg.max_queue:
                if not block:
                    raise AdmissionError(
                        f"queue full ({self._depth}/{self.cfg.max_queue} "
                        f"images queued) — backpressure, retry later")
                ok = self._cv.wait_for(
                    lambda: self._closed
                    or self._depth + n <= self.cfg.max_queue, timeout)
                if not ok or self._closed:
                    raise AdmissionError("queue full (timed out blocking)"
                                         if not self._closed else
                                         "batcher closed")
            self._q.append(_Entry(images, keys, slot, time.perf_counter()))
            self._depth += n
            self._cv.notify_all()

    def depth(self) -> int:
        """Queued images (admission-control view of the backlog)."""
        with self._cv:
            return self._depth

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def flush(self) -> List[_Entry]:
        """Drain and return whatever is still queued — the shutdown
        path, so a forced close can reject the orphaned requests
        instead of leaving their futures unresolved."""
        with self._cv:
            take, self._q = self._q, []
            self._depth = 0
            self._cv.notify_all()
            return take

    # -- coalescing ---------------------------------------------------------
    def next_batch(self, timeout: Optional[float] = None
                   ) -> Optional[MicroBatch]:
        """Block until a micro-batch is ready (or ``timeout``); returns
        None on timeout or when closed and empty.

        Ships when ``max_batch`` images are queued or the oldest
        request's ``max_wait_ms`` deadline expires — whichever first."""
        cfg = self.cfg
        with self._cv:
            if not self._cv.wait_for(lambda: self._q or self._closed,
                                     timeout):
                return None
            if not self._q:
                return None          # closed and empty
            deadline = self._q[0].t_enq + cfg.max_wait_ms / 1e3
            while (not self._closed and self._depth < cfg.max_batch):
                rem = deadline - time.perf_counter()
                if rem <= 0:
                    break
                self._cv.wait(rem)
                if not self._q:      # drained by close() race
                    return None
            # pop whole requests up to max_batch (groups stay atomic)
            take: List[_Entry] = []
            total = 0
            while self._q and total + self._q[0].images.shape[0] \
                    <= cfg.max_batch:
                e = self._q.pop(0)
                take.append(e)
                total += e.images.shape[0]
            self._depth -= total
            self._cv.notify_all()    # wake blocked submitters
        assert take, "next_batch woke with an un-poppable queue head"
        raw = (take[0].images if len(take) == 1
               else np.concatenate([e.images for e in take]))
        keys = (take[0].keys if len(take) == 1
                else jnp.concatenate([e.keys for e in take]))
        raw, true_b = pad_to_bucket(raw, cfg.bucket)
        pad = raw.shape[0] - true_b
        if pad:
            # pad keys like the images: repeated rows are inert (results
            # sliced off before the scatter), any key value works
            keys = jnp.concatenate([keys, jnp.repeat(keys[-1:], pad,
                                                     axis=0)])
        slots, off = [], 0
        for e in take:
            n = e.images.shape[0]
            slots.append((e.slot, off, n))
            off += n
        return MicroBatch(raw=raw, keys=keys, slots=slots, true_b=true_b,
                          padded_b=raw.shape[0],
                          t_formed=time.perf_counter())
