"""Fleet replica: one :class:`~repro.serving.server.DetectionServer`
wrapped for fleet membership and fault injection.

A :class:`Replica` is the unit the :class:`~repro.serving.router
.FleetRouter` fronts — it owns a full single-process serving runtime
(micro-batcher, service-mode lane executor, straggler watchdog,
caches) plus the three things a fleet needs on top:

* **identity + placement** — a stable ``name`` (the rendezvous-hash
  token) and an optional jax ``device`` pin, so N in-process replicas
  spread over N forced CPU devices (the ``sharded_check.py``
  CI-scale fleet simulation: ``--xla_force_host_platform_device_count``);
* **health** — ``healthy`` flips to False exactly once, on
  :meth:`crash`; a crashed replica rejects every in-flight and queued
  request with :class:`ReplicaCrashed` (via ``DetectionServer.kill``),
  which is the signal the router's re-execution path keys on;
* **fault injection** — an injectable :class:`FaultPlan` consulted at
  the replica's public seams (submit admission, post-admission,
  drain).  Tests and the fig14 chaos arm express failure scenarios as
  data instead of monkeypatching server internals, and the injection
  points are part of the wrapper's contract, not its implementation.

The wrapper deliberately adds **no routing logic**: which replica gets
a request, spill-over, and re-execution live in the router; the
replica only answers "can you take this" (admission), "how loaded are
you" (:meth:`load`), and "are you alive" (:attr:`healthy`).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional

import numpy as np

from repro.serving.batcher import AdmissionError, BatcherConfig
from repro.serving.server import DetectionServer, RequestHandle


class ReplicaCrashed(RuntimeError):
    """A replica died with this request in its hands (or was asked to
    take it after dying).  The router treats this as re-executable:
    the request never produced a result, so re-running it on a healthy
    sibling is exact, not at-most-once-violating."""


@dataclasses.dataclass
class FaultPlan:
    """Injectable failure schedule, consulted at the replica's seams.

    All fields count *this replica's* submit attempts (0-based order of
    arrival at :meth:`Replica.submit`), so tests can pin a fault to an
    exact request without reaching into server internals:

    * ``reject_submits`` — the next N submits raise
      :class:`AdmissionError` (induced backpressure; the router must
      spill over, counted as ``spillovers``);
    * ``crash_at_submit`` — crash *instead of admitting* submit #k:
      the request never enters this replica, the router re-routes it;
    * ``crash_after_admit`` — admit submit #k normally, then crash
      while it is in flight (mid-batch): its handle — and every other
      in-flight request here — rejects with :class:`ReplicaCrashed`
      and must resolve via sibling re-execution;
    * ``crash_on_drain`` — crash the next time the router drains this
      replica (the crash-during-drain / rolling-reconfigure scenario).
    """
    reject_submits: int = 0
    crash_at_submit: Optional[int] = None
    crash_after_admit: Optional[int] = None
    crash_on_drain: bool = False


class Replica:
    """One fleet member: a named, optionally device-pinned
    :class:`DetectionServer` with health state and fault injection."""

    def __init__(self, name: str, cfg, params, *,
                 batcher: Optional[BatcherConfig] = None,
                 lanes: Optional[Dict[str, int]] = None,
                 device=None,
                 fault_plan: Optional[FaultPlan] = None,
                 **server_kw):
        self.name = name
        self.plan = fault_plan or FaultPlan()
        self.srv = DetectionServer(cfg, params, batcher=batcher,
                                   lanes=lanes, device=device,
                                   name=f"replica/{name}", **server_kw)
        self._lock = threading.Lock()
        self._dead = False
        self._closed = False
        self._submit_seq = 0   # arrival order, the FaultPlan's clock

    # -- lifecycle ----------------------------------------------------
    def start(self) -> "Replica":
        self.srv.start()
        return self

    def warmup(self, sample_image: np.ndarray):
        return self.srv.warmup(sample_image)

    def close(self):
        """Graceful shutdown (drains).  Crashed replicas are already
        torn down — close() on one is a no-op, not a second teardown."""
        with self._lock:
            if self._dead or self._closed:
                return
            self._closed = True
        self.srv.close()

    def kill(self, error: Optional[BaseException] = None):
        """Abrupt shutdown with a caller-supplied rejection error (the
        router's non-graceful close path).  Unlike :meth:`crash` the
        replica counts as *closed*, not crashed — pending requests
        reject with ``error``, and the router's closed flag (set
        before killing) keeps those rejections from triggering
        re-routes."""
        with self._lock:
            if self._dead or self._closed:
                return
            self._closed = True
        self.srv.kill(error)

    def crash(self, reason: str = "fault injection"):
        """Simulated process death: flips ``healthy`` exactly once and
        abruptly kills the server — every in-flight and queued request
        here rejects with :class:`ReplicaCrashed` through its handle
        callbacks, which is what drives the router's re-execution."""
        with self._lock:
            if self._dead or self._closed:
                return
            self._dead = True
        self.srv.kill(ReplicaCrashed(
            f"replica {self.name} crashed ({reason})"))

    @property
    def healthy(self) -> bool:
        with self._lock:
            return not self._dead and not self._closed

    # -- fault-plan seams --------------------------------------------
    def _tick_submit(self) -> int:
        with self._lock:
            seq = self._submit_seq
            self._submit_seq += 1
        return seq

    # -- serving surface ---------------------------------------------
    def submit(self, images: np.ndarray, *, key=None,
               priority: Optional[str] = None,
               block: bool = False) -> RequestHandle:
        """Admit one request on this replica.  Consults the fault plan
        first: induced rejections and crashes happen at this seam, in
        arrival order, exactly as a real replica would fail — before
        or after admission, never half-way through the server's own
        bookkeeping."""
        seq = self._tick_submit()
        plan = self.plan
        if plan.crash_at_submit is not None and \
                seq >= plan.crash_at_submit:
            self.crash(f"crash_at_submit={plan.crash_at_submit}")
        if not self.healthy:
            raise ReplicaCrashed(f"replica {self.name} is down")
        if plan.reject_submits > 0:
            with self._lock:
                induced = plan.reject_submits > 0
                if induced:
                    plan.reject_submits -= 1
            if induced:
                self.srv.metrics.count("faults_injected")
                raise AdmissionError(
                    f"replica {self.name}: induced backpressure "
                    f"(fault plan)")
        handle = self.srv.submit(images, key=key, priority=priority,
                                 block=block)
        if plan.crash_after_admit is not None and \
                seq >= plan.crash_after_admit:
            self.crash(f"crash_after_admit={plan.crash_after_admit}")
        return handle

    def drain(self, timeout: Optional[float] = None) -> bool:
        if self.plan.crash_on_drain:
            self.plan.crash_on_drain = False
            self.crash("crash_on_drain")
            return False
        if not self.healthy:
            return False
        return self.srv.drain(timeout)

    def reconfigure(self, lanes: Dict[str, int]) -> Dict[str, int]:
        if not self.healthy:
            raise ReplicaCrashed(f"replica {self.name} is down")
        return self.srv.reconfigure(lanes)

    def load(self) -> Dict[str, int]:
        """Queue depth / in-flight / admission headroom (the router's
        least-loaded spill-over metric).  A dead replica reports zero
        headroom and infinite-equivalent depth so it always sorts
        last even if a stale poll races the crash."""
        if not self.healthy:
            return {"queue_depth": 1 << 30, "inflight_requests": 1 << 30,
                    "headroom": 0}
        return self.srv.load()

    def stats(self) -> dict:
        return self.srv.stats()

    def __repr__(self):
        state = "up" if self.healthy else "down"
        return f"Replica({self.name!r}, {state})"
