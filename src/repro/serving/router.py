"""Multi-replica fleet router: Algorithm 1's resource-aware policy,
generalized from lanes-within-a-process to replicas-across-a-fleet.

:class:`FleetRouter` fronts N :class:`~repro.serving.replica.Replica`
instances (each a full :class:`DetectionServer` runtime, thread-per-
replica in-process, optionally pinned to its own forced CPU device for
CI-scale fleet simulation) behind the same ``submit() -> handle``
surface a single server exposes, so the Poisson load generator and the
benchmarks drive a fleet exactly like one server.

Routing disciplines, in the order a request meets them::

    submit(images) ──► content digest (sha256, the cache key material)
        ──► rendezvous hash over healthy in-rotation replicas
            (identical pixels -> identical replica, so ``cache_exact``
            traffic always lands on the replica that holds its entry;
            add/remove one replica remaps ~1/N of the keyspace)
        ──► AdmissionError? spill over to the least-loaded healthy
            sibling (queue depth + in-flight via the batcher's
            backpressure surface; counted as ``spillovers``)
        ──► replica crash mid-flight? the dead replica rejects the
            request THROUGH its handle callback; the router re-executes
            it on a healthy sibling (counted as ``reroutes``) —
            stage fns are pure and keys derive from content/request,
            never placement, so re-execution is exact and
            first-completion-wins is safe (the straggler-monitor
            discipline, one level up)
        ──► FleetHandle.result()

**Bit-identity contract**: routing must never change results.  Request
keys derive from explicit caller keys or from content
(``cache_exact``), so the same request set through 1, 2, or N replicas
— under any spill-over or re-execution history — is bitwise identical
to one ``DetectionServer`` (asserted by ``tests/test_fleet.py``).

**Rolling reconfigure** (:meth:`rolling_reconfigure`): one replica at
a time is taken out of rotation (new traffic routes to siblings),
drained, ``reconfigure()``-d live, and returned — zero dropped
requests.  A replica that crashes while draining is marked unhealthy
and skipped, never wedging the roll.

**Health**: a poller thread watches replica health and per-replica
queue depth; a crashed replica leaves rotation exactly once (counted
as ``unhealthy``) and its in-flight work re-executes as above.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.serving import cache as cache_lib
from repro.serving.batcher import AdmissionError
from repro.serving.metrics import MetricsRegistry, aggregate_counters
from repro.serving.replica import Replica, ReplicaCrashed
from repro.serving.server import RequestHandle


def rendezvous_order(digest: bytes, names: Sequence[str]) -> List[str]:
    """Highest-random-weight (rendezvous) preference order of
    ``names`` for a request digest: every (digest, name) pair gets an
    independent hash score and names sort by it.  Properties the fleet
    leans on — deterministic (identical digests always order
    identically), and minimal-disruption (removing a name only remaps
    digests that ranked it first, ~1/N of the keyspace; adding one
    steals ~1/(N+1) and moves nothing else)."""
    return sorted(
        names,
        key=lambda n: hashlib.blake2b(
            n.encode() + digest, digest_size=8).digest(),
        reverse=True)


def rendezvous(digest: bytes, names: Sequence[str]) -> str:
    """The owning replica for a digest (first of the preference
    order).  Raises on an empty name set."""
    if not names:
        raise ValueError("rendezvous over an empty replica set")
    return rendezvous_order(digest, names)[0]


class FleetHandle(RequestHandle):
    """Future for one fleet request.  Extends the server handle with
    the routing history the tests and the chaos benchmark read:
    ``replica`` (where it last executed), ``spilled`` (admission
    spill-over happened) and ``reroutes`` (crash re-executions)."""

    def __init__(self, rid: int, n: int, priority: str = "default"):
        super().__init__(rid, n, priority=priority)
        self.replica: Optional[str] = None
        self.spilled = False
        self.reroutes = 0


class _FleetReq:
    """Router-side state for one in-flight fleet request."""

    def __init__(self, fh: FleetHandle, images: np.ndarray, key,
                 priority: Optional[str], digest: bytes):
        self.fh = fh
        self.images = images
        self.key = key
        self.priority = priority
        self.digest = digest
        self.tried: Set[str] = set()   # admitted-then-crashed replicas
        self.settled = False


class FleetRouter:
    """Front-end over N detection replicas (rendezvous routing,
    spill-over, crash re-execution, rolling reconfigure)."""

    def __init__(self, replicas: Sequence[Replica], *,
                 poll_interval_s: float = 0.02):
        if not replicas:
            raise ValueError("FleetRouter needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique: {names}")
        self._replicas: Dict[str, Replica] = {r.name: r for r in replicas}
        self.metrics = MetricsRegistry()
        self._lock = threading.Lock()
        self._rotation: Dict[str, bool] = {n: True for n in names}
        self._known_dead: Set[str] = set()
        self._pending: Dict[int, _FleetReq] = {}
        self._req_seq = 0
        self._closed = False
        self._stop = threading.Event()
        self._poll_interval = poll_interval_s
        self._threads: List[threading.Thread] = []

    # -- lifecycle ----------------------------------------------------
    def start(self) -> "FleetRouter":
        for r in self._replicas.values():
            r.start()
        poller = threading.Thread(target=self._poll_loop, daemon=True,
                                  name="fleet-router/health")
        poller.start()
        self._threads.append(poller)
        return self

    def warmup(self, sample_image: np.ndarray):
        """Warm every replica's jit caches (each replica compiles its
        own graphs — separate pipelines, possibly separate devices)."""
        out = {}
        for name, r in self._replicas.items():
            out[name] = r.warmup(sample_image)
        return out

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Wait until every fleet handle has settled — this covers
        spill-over and re-execution windows where a request belongs to
        no replica queue (it is between replicas, in the router's
        hands)."""
        t_end = (time.perf_counter() + timeout
                 if timeout is not None else None)
        while True:
            with self._lock:
                idle = not self._pending
            if idle:
                return True
            if t_end is not None and time.perf_counter() > t_end:
                return False
            time.sleep(0.002)

    def close(self, *, graceful: bool = True,
              drain_timeout: float = 30.0):
        """Shut the fleet down.  ``graceful`` drains in-flight work
        first (every handle resolves with its result); ``graceful=
        False`` kills the replicas and rejects every pending handle —
        in both modes each handle settles **exactly once** (the
        ``_FleetReq.settled`` flag is the single settlement gate, and
        the closed flag set first means no rejection can trigger a
        re-route)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._stop.set()
        if graceful:
            self.drain(drain_timeout)
        err = RuntimeError("fleet router closed")
        for r in self._replicas.values():
            if graceful:
                r.close()
            else:
                r.kill(err)
        # anything still unsettled (e.g. a request that was between
        # replicas when a non-graceful close landed) rejects here —
        # the settled flag makes a racing late callback a no-op
        with self._lock:
            leftovers = list(self._pending.values())
        for req in leftovers:
            self._settle(req, error=err)
        me = threading.current_thread()
        for t in self._threads:
            if t is not me:
                t.join(timeout=2.0)

    # -- health -------------------------------------------------------
    def _mark_unhealthy(self, name: str):
        with self._lock:
            if name in self._known_dead:
                return
            self._known_dead.add(name)
            self._rotation[name] = False
        self.metrics.count("unhealthy")

    def _poll_loop(self):
        while not self._stop.is_set():
            for name, r in self._replicas.items():
                if not r.healthy:
                    self._mark_unhealthy(name)
                    continue
                load = r.load()
                self.metrics.gauge(f"replica_{name}_depth",
                                   load["queue_depth"])
                self.metrics.gauge(f"replica_{name}_inflight",
                                   load["inflight_requests"])
            self.metrics.gauge("healthy_replicas", sum(
                r.healthy for r in self._replicas.values()))
            self._stop.wait(self._poll_interval)

    def healthy_replicas(self) -> List[str]:
        return [n for n, r in self._replicas.items() if r.healthy]

    # -- routing ------------------------------------------------------
    def _candidates(self, digest: bytes, tried: Set[str]) -> List[str]:
        """Attempt order for one dispatch pass: the rendezvous owner
        among healthy in-rotation replicas first, then the remaining
        in-rotation siblings least-loaded first (the spill-over
        order), then out-of-rotation-but-healthy replicas as a last
        resort (a mid-roll fleet must still take every request —
        rolling reconfigure drops nothing)."""
        with self._lock:
            rot = [n for n, r in self._replicas.items()
                   if r.healthy and self._rotation[n] and n not in tried]
            out = [n for n, r in self._replicas.items()
                   if r.healthy and not self._rotation[n]
                   and n not in tried]
        if not rot and not out:
            return []
        order: List[str] = []
        if rot:
            ranked = rendezvous_order(digest, rot)
            order.append(ranked[0])
            rest = ranked[1:]
            # spill-over order: least queued work first; digest rank
            # breaks ties deterministically
            rank = {n: i for i, n in enumerate(ranked)}
            rest.sort(key=lambda n: (self._load_score(n), rank[n]))
            order.extend(rest)
        if out:
            rank_out = {n: i for i, n in
                        enumerate(rendezvous_order(digest, out))}
            out.sort(key=lambda n: (self._load_score(n), rank_out[n]))
            order.extend(out)
        return order

    def _load_score(self, name: str) -> int:
        load = self._replicas[name].load()
        return load["queue_depth"] + load["inflight_requests"]

    def submit(self, images: np.ndarray, *, key=None,
               priority: Optional[str] = None,
               block: bool = False) -> FleetHandle:
        """Admit one request to the fleet.  Raises
        :class:`AdmissionError` when no healthy replica will take it
        (whole-fleet backpressure) — mirroring a single server's
        surface so load generators need not know they talk to N."""
        with self._lock:
            if self._closed:
                raise AdmissionError("fleet router closed")
            rid = self._req_seq
            self._req_seq += 1
        images = np.asarray(images)
        if images.ndim == 3:
            images = images[None]
        if images.shape[0] == 0:
            self.metrics.count("requests_rejected")
            raise AdmissionError("empty request (0 images)")
        digest = cache_lib.request_digest(images)
        fh = FleetHandle(rid, images.shape[0],
                         priority=priority or "default")
        req = _FleetReq(fh, images, key, priority, digest)
        with self._lock:
            self._pending[rid] = req
        try:
            self._dispatch(req, block=block)
        except AdmissionError:
            with self._lock:
                self._pending.pop(rid, None)
                req.settled = True
            self.metrics.count("requests_rejected")
            raise
        self.metrics.count("requests_admitted")
        return fh

    def _dispatch(self, req: _FleetReq, *, block: bool = False):
        """One placement pass: try candidates in routing order until a
        replica admits the request; hook the underlying handle so
        completion (or a crash rejection) flows back through
        :meth:`_on_underlying`.  Raises :class:`AdmissionError` when
        every candidate refused."""
        last_err: Optional[BaseException] = None
        spilled = False
        for name in self._candidates(req.digest, req.tried):
            r = self._replicas[name]
            try:
                uh = r.submit(req.images, key=req.key,
                              priority=req.priority, block=block)
            except AdmissionError as e:
                last_err = e
                spilled = True
                continue
            except ReplicaCrashed as e:
                last_err = e
                self._mark_unhealthy(name)
                continue
            req.fh.replica = name
            if spilled:
                req.fh.spilled = True
                self.metrics.count("spillovers")
            uh.add_done_callback(
                lambda h, req=req, rep=r: self._on_underlying(req, rep,
                                                              h))
            return
        raise AdmissionError(
            "no healthy replica admitted the request "
            f"(fleet backpressure; last: {last_err})")

    def _on_underlying(self, req: _FleetReq, replica: Replica, uh):
        """Settlement hook, called exactly once per underlying handle.
        Success settles the fleet handle (first completion wins).  A
        rejection from a replica that died re-executes on a sibling —
        the crash analogue of straggler speculation; any other error
        (or an exhausted fleet) propagates to the caller's handle."""
        try:
            result = uh.result(0)
            err = None
        except BaseException as e:   # includes ReplicaCrashed
            result, err = None, e
        if err is None:
            self._settle(req, result=result)
            return
        crashed = isinstance(err, ReplicaCrashed) or not replica.healthy
        with self._lock:
            closed = self._closed
        if crashed and not closed:
            self._mark_unhealthy(replica.name)
            req.tried.add(replica.name)
            req.fh.reroutes += 1
            self.metrics.count("reroutes")
            try:
                self._dispatch(req)
                return
            except AdmissionError as e:
                err = e
        self._settle(req, error=err)

    def _settle(self, req: _FleetReq, *, result=None, error=None):
        with self._lock:
            if req.settled:
                return
            req.settled = True
            self._pending.pop(req.fh.rid, None)
        if error is None:
            req.fh._resolve(result)
            self.metrics.count("requests_completed")
            self.metrics.count("images_completed",
                               result["message_bits"].shape[0])
            self.metrics.observe("request_latency_s", req.fh.latency_s)
        else:
            req.fh._reject(error)
            self.metrics.count("requests_failed")

    # -- rolling reconfigure ------------------------------------------
    def _set_rotation(self, name: str, in_rotation: bool):
        with self._lock:
            if name not in self._known_dead:
                self._rotation[name] = in_rotation

    def rolling_reconfigure(self, lanes: Optional[Dict[str, int]] = None,
                            *, drain_timeout: float = 30.0
                            ) -> Dict[str, Dict[str, int]]:
        """Reconfigure the fleet one replica at a time with zero
        dropped requests: take a replica out of rotation (new traffic
        rendezvous-routes to its siblings; an out-of-rotation replica
        only takes traffic when it is the last healthy one), drain it,
        apply the lane map (``None`` re-applies its current lanes),
        and return it.  A replica that crashes while draining is
        marked unhealthy and skipped — its in-flight work re-executes
        on siblings through the normal crash path."""
        applied: Dict[str, Dict[str, int]] = {}
        for name in list(self._replicas):
            r = self._replicas[name]
            if not r.healthy:
                continue
            self._set_rotation(name, False)
            try:
                r.drain(drain_timeout)
                if not r.healthy:        # crash-during-drain
                    self._mark_unhealthy(name)
                    continue
                target = dict(lanes) if lanes else r.srv.lane_counts()
                applied[name] = r.reconfigure(target)
                self.metrics.count("reconfigures")
            except ReplicaCrashed:
                self._mark_unhealthy(name)
                continue
            finally:
                if r.healthy:
                    self._set_rotation(name, True)
        return applied

    # -- reporting ----------------------------------------------------
    def stats(self) -> dict:
        """Fleet-level report: the router's own funnel (admissions,
        spill-overs, re-routes, fleet-wide latency percentiles) plus
        the exact sum of every replica's counters and a per-replica
        health/load table."""
        out = self.metrics.snapshot()
        rep_stats = {n: r.stats() for n, r in self._replicas.items()}
        out["fleet_counters"] = aggregate_counters(rep_stats.values())
        out["straggler_retries"] = int(sum(
            s.get("straggler_retries", 0) for s in rep_stats.values()))
        out["replicas"] = {
            n: {"healthy": r.healthy,
                "in_rotation": self._rotation[n],
                **(r.load() if r.healthy else {})}
            for n, r in self._replicas.items()}
        for c in ("spillovers", "reroutes", "unhealthy"):
            out[c] = int(self.metrics.counter(c))
        with self._lock:
            out["pending"] = len(self._pending)
        return out
