"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf] 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2.  Within every 8-layer group, layer 4 is
attention and the other 7 are Mamba (1:7); every other layer uses the MoE
MLP (Jamba applies MoE at period 2).  We standardise the SSM blocks on
Mamba-2/SSD with d_state=128 (Jamba-1 used Mamba-1 d_state=16; recorded as a
hardware-adaptation change in DESIGN.md — SSD's matmul form is the
TPU-native formulation).
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    moe=MoEConfig(n_experts=16, top_k=2, period=2, offset=1),
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1),
    attn_period=8,
    attn_offset=4,
    source="arXiv:2403.19887; hf",
))
