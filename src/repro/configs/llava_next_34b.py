"""LLaVA-NeXT 34B — VLM text backbone with anyres image tiling.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] 60L d_model=7168 56H
(GQA kv=8) d_ff=20480 vocab=64000.  The vision tower is a STUB per the
assignment: input_specs() provides 2880 precomputed anyres patch embeddings
(4 tiles + base image x 576 patches) prepended to the text sequence.
The anyres tiling of the vision side is the one assigned arch whose
workload shape matches QRMark's tile scheduling (see DESIGN.md).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    frontend="vision",
    n_frontend_tokens=2880,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))
