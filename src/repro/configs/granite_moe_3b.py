"""Granite-3.0 MoE 3B (800M active) — 40-expert top-8 fine-grained MoE.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 32L d_model=1536 24H
(GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 40e top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    moe=MoEConfig(n_experts=40, top_k=8),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
))
