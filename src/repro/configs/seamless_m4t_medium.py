"""SeamlessM4T-medium — encoder-decoder multimodal (audio frontend stub).

[arXiv:2308.11596; hf] 12L (encoder) + 12L (decoder) d_model=1024 16H
(kv=16, i.e. MHA) d_ff=4096 vocab=256206.  The speech frontend is a STUB:
input_specs() provides precomputed frame embeddings consumed by the text
encoder; the decoder cross-attends to encoder output.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    is_encoder_decoder=True,
    n_enc_layers=12,
    frontend="audio",
    source="arXiv:2308.11596; hf",
))
