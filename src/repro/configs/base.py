"""Architecture + shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; every workload
shape is a :class:`ShapeSpec`.  The dry-run, smoke tests, trainers and the
roofline harness all consume these.  Configs are *data*, never code: the
model assembly in ``repro.models.lm`` interprets them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # Every ``period``-th layer (offset ``offset``) uses the MoE MLP; others
    # use the dense MLP.  period=1 -> every layer is MoE.
    period: int = 1
    offset: int = 0
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyperparameters."""

    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length for the matmul-form scan


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: within each block of ``attn_period`` layers, layer
    # index ``attn_offset`` is attention, the rest are SSM (jamba-style 1:7).
    attn_period: int = 0
    attn_offset: int = 0
    sliding_window: int = 0  # 0 -> full attention
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: none | vision | audio.  Frontend embeddings are
    # provided pre-computed by input_specs() per the assignment instructions.
    frontend: str = "none"
    n_frontend_tokens: int = 0  # e.g. image patches prepended to the text seq
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # source tag from the assignment table
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived quantities -------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' for layer i of the backbone."""
        if self.family == "ssm":
            return "ssm"
        if self.attn_period:
            return "attn" if (i % self.attn_period) == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        return (i % self.moe.period) == self.moe.offset

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can run the 500k-token long-context shape.

        SSM and hybrid archs are O(s) per token; sliding-window attention
        bounds the KV cache at the window size.  Pure full-attention archs
        are excluded per the assignment instructions.
        """
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    # -- parameter count (exact, mirrors models.lm.init) --------------------
    def param_counts(self) -> dict:
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        counts = {"embed": V * d, "head": 0 if self.tie_embeddings else d * V,
                  "final_norm": d}
        attn_p = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        dense_mlp = 3 * d * ff  # SwiGLU: w_gate, w_up, w_down
        ssm_p = 0
        if self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            ds, ng, cw = self.ssm.d_state, self.ssm.n_groups, self.ssm.conv_width
            conv_dim = di + 2 * ng * ds
            ssm_p = (
                d * (2 * di + 2 * ng * ds + nh)  # in_proj (z,x,B,C,dt)
                + conv_dim * cw                   # depthwise conv
                + nh                              # A_log
                + nh                              # D skip
                + nh                              # dt_bias
                + di * d                          # out_proj
                + di                              # pre-out norm
            )
        total = counts["embed"] + counts["head"] + counts["final_norm"]
        act_total = total  # "active" params for MoE MODEL_FLOPS
        n_backbone = self.n_layers
        for i in range(n_backbone):
            kind = self.layer_kind(i)
            has_mlp = self.layer_is_moe(i) or ff > 0
            lp = d * (2 if has_mlp else 1)  # RMSNorm scales
            lp_act = lp
            if kind == "attn":
                lp += attn_p
                lp_act += attn_p
            else:
                lp += ssm_p
                lp_act += ssm_p
            if self.layer_is_moe(i):
                m = self.moe
                lp += m.n_experts * dense_mlp + d * m.n_experts  # experts+router
                lp_act += m.top_k * dense_mlp + d * m.n_experts
            else:
                lp += dense_mlp
                lp_act += dense_mlp
            total += lp
            act_total += lp_act
        if self.is_encoder_decoder:
            # encoder layers (full attn, dense MLP) + cross-attention in
            # decoder layers + the encoder's final norm
            enc_layer = attn_p + dense_mlp + 2 * d
            cross = attn_p + d
            extra = self.n_enc_layers * enc_layer + self.n_layers * cross \
                + d  # enc_norm
            total += extra
            act_total += extra
        counts["total"] = total
        counts["active"] = act_total
        return counts


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_enabled(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell, and why not if not."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def all_configs() -> dict:
    _load_all()
    return dict(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    import importlib

    for mod in (
        "jamba_1_5_large_398b",
        "phi3_5_moe_42b",
        "granite_moe_3b",
        "llava_next_34b",
        "smollm_360m",
        "mistral_large_123b",
        "h2o_danube3_4b",
        "mistral_nemo_12b",
        "mamba2_2_7b",
        "seamless_m4t_medium",
    ):
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=max(2, cfg.attn_period or 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=256,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=4, top_k=min(2, cfg.moe.top_k), period=cfg.moe.period,
            offset=cfg.moe.offset, capacity_factor=cfg.moe.capacity_factor)
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(d_state=16, expand=2, head_dim=16,
                                   n_groups=1, conv_width=4, chunk=32)
    if cfg.attn_period:
        changes["n_layers"] = cfg.attn_period  # one full interleave group
    if cfg.is_encoder_decoder:
        changes["n_enc_layers"] = 2
        changes["n_layers"] = 2
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    if cfg.n_frontend_tokens:
        changes["n_frontend_tokens"] = 8
    changes["name"] = cfg.name + "-reduced"
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
