"""Mamba2 2.7B — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified] 64L d_model=2560 vocab=50280 ssm_state=128.
expand=2 -> d_inner=5120, head_dim=64 -> 80 SSM heads.  Runs long_500k
(O(1) state per token).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1),
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
