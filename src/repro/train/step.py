"""Train / prefill / decode step functions, microbatched and shardable.

``make_train_step`` builds the jit-able function the launcher and dry-run
lower: gradient accumulation over microbatches (lax.scan), remat inside
each microbatch, global-norm clipping and AdamW — all expressed so GSPMD
can place the grad reduce-scatter/all-gather for the ZeRO/FSDP shardings
from the planner.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.train import optimizer as opt_lib


def make_train_step(cfg, opt_cfg, *, n_micro=1, compute_dtype=jnp.bfloat16,
                    grad_compress=False, remat=True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics)."""

    def loss_fn(params, mb):
        return lm.forward_train(params, mb, cfg, compute_dtype=compute_dtype,
                                remat=remat)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)
            acc_dtype = jnp.bfloat16 if grad_compress else jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              params)

            def body(carry, mb):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), g_acc, g)
                return (loss_acc + loss, g_acc), None

            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), g0), mbs)
            loss = loss / n_micro
            grads = jax.tree.map(lambda g: (g / n_micro).astype(jnp.float32),
                                 grads)
        params, opt_state, metrics = opt_lib.adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, *, compute_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        return lm.forward_prefill(params, batch, cfg,
                                  compute_dtype=compute_dtype)
    return prefill_step


def make_decode_step(cfg, *, compute_dtype=jnp.bfloat16):
    def decode_step(params, tokens, state):
        return lm.forward_decode(params, tokens, state, cfg,
                                 compute_dtype=compute_dtype)
    return decode_step
