"""AdamW from scratch (no optax), pytree-native, with global-norm clipping
and an optional bf16 gradient-compression stage.

The optimizer state mirrors the parameter pytree (fp32 master weights live
in ``params`` itself; moments are fp32).  Under the sharding plan the
moments are ZeRO-1 sharded across the data axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        norm


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    # flatten to avoid tuple-leaf ambiguity (group params are tuples)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    res = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [r[0] for r in res])
    new_state = {"m": jax.tree.unflatten(treedef, [r[1] for r in res]),
                 "v": jax.tree.unflatten(treedef, [r[2] for r in res]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
