"""LM training launcher: mesh setup, sharded state init, checkpoint/
restart, async saves, elastic rescale, and the QRMark-style interleaved
input pipeline.

This is the end-to-end driver used by the examples (CPU-local mesh) and
by a real deployment (production mesh, same code path):

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 200 --reduced --batch 8 --seq 128

Fault-tolerance behaviour:
* saves every ``--ckpt-every`` steps (async, atomic);
* on start, resumes from the latest valid checkpoint if present;
* ``--simulate-failure N`` aborts the process hard at step N (used by the
  integration tests to prove restart works);
* restoring onto a different device count re-shards transparently
  (elastic rescale) because restore() lays out against the *current*
  mesh's shardings.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import base as cfgbase
from repro.core.interleave import interleaved
from repro.data import pipeline as data_lib
from repro.launch import mesh as mesh_lib
from repro.models import lm
from repro.sharding import planner
from repro.train import optimizer as opt_lib, step as step_lib
from repro.ckpt import checkpoint as ckpt_lib


def build_state(cfg, mesh, plan, seed=0):
    pspecs = planner.param_specs(cfg, lm.abstract_params(cfg), plan)
    pshard = planner.to_shardings(pspecs, mesh)
    with mesh:
        params = jax.jit(
            lambda k: lm.init_params(cfg, k),
            out_shardings=pshard)(jax.random.key(seed))
        ospec = {"m": planner.opt_specs(cfg, lm.abstract_params(cfg), plan),
                 "v": planner.opt_specs(cfg, lm.abstract_params(cfg), plan),
                 "step": jax.sharding.PartitionSpec()}
        oshard = planner.to_shardings(ospec, mesh)
        opt_state = jax.jit(opt_lib.init_opt_state,
                            out_shardings=oshard)(params)
    return params, opt_state, pshard, oshard


def train_loop(cfg, shape, *, steps, mesh=None, opt_cfg=None, ckpt_dir=None,
               ckpt_every=50, keep=3, seed=0, simulate_failure=None,
               log_every=10, verbose=True):
    mesh = mesh or mesh_lib.make_local_mesh()
    plan = planner.make_plan(cfg, shape, mesh)
    opt_cfg = opt_cfg or opt_lib.AdamWConfig(
        total_steps=steps, lr=1e-3,
        warmup_steps=max(1, min(100, steps // 10)))
    params, opt_state, pshard, oshard = build_state(cfg, mesh, plan, seed)

    start_step = 0
    ckpt = None
    if ckpt_dir is not None:
        ckpt = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=keep)
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            with mesh:
                params = ckpt_lib.restore(ckpt_dir, last, params,
                                          shardings=pshard)
                opt_state = ckpt_lib.restore(
                    Path(ckpt_dir) / "opt", last, opt_state,
                    shardings=oshard) if (Path(ckpt_dir) / "opt").exists() \
                    else opt_state
            start_step = last
            if verbose:
                print(f"[train] resumed from step {last}", flush=True)

    step_fn = step_lib.make_train_step(cfg, opt_cfg, n_micro=plan.n_micro)
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        batches = interleaved(
            data_lib.lm_batches(cfg, shape, n_steps=steps - start_step,
                                seed=seed, start_step=start_step),
            depth=2)
        hist = []
        t0 = time.time()
        for i, batch in enumerate(batches):
            step_idx = start_step + i
            if simulate_failure is not None and step_idx == simulate_failure:
                os._exit(42)  # hard crash: no cleanup, no final save
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if ckpt is not None and (step_idx + 1) % ckpt_every == 0:
                ckpt.save(step_idx + 1, params)
                ckpt_lib.save(Path(ckpt_dir) / "opt", step_idx + 1,
                              jax.tree.map(np.asarray, opt_state),
                              keep=keep)
            if step_idx % log_every == 0 or step_idx == steps - 1:
                loss = float(metrics["loss"])
                hist.append({"step": step_idx, "loss": loss,
                             "grad_norm": float(metrics["grad_norm"]),
                             "wall_s": time.time() - t0})
                if verbose:
                    print(f"[train] step {step_idx:5d} loss={loss:.4f} "
                          f"gnorm={hist[-1]['grad_norm']:.2f}", flush=True)
        if ckpt is not None:
            ckpt.wait()
    return {"params": params, "opt_state": opt_state, "history": hist,
            "plan": plan}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--simulate-failure", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfgbase.get_config(args.arch)
    if args.reduced:
        cfg = cfgbase.reduced(cfg)
    shape = cfgbase.ShapeSpec("custom", args.seq, args.batch, "train")
    out = train_loop(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every,
                     simulate_failure=args.simulate_failure,
                     seed=args.seed)
    print(json.dumps(out["history"][-3:], indent=1))


if __name__ == "__main__":
    main()
