import os

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell, prove it fits (memory_analysis), and extract the roofline raw
terms (cost_analysis + HLO collective traffic).

Because XLA cost analysis counts a while-loop body ONCE, the scan-over-
layers/microbatch costs are measured with *unrolled probes*: the same step
function at depth 1 and 2 layer-groups (python-unrolled), same mesh and
shardings; the per-group cost is the difference, and the full-depth cost
is  A + n_groups * B  (x n_micro for the gradient-accumulation scan, plus
an analytic optimizer term).  The full-depth scan version is still
compiled for real — that is the artifact that proves the cell works.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all [--force]
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.launch import hlo_analysis, mesh as mesh_lib
from repro.models import lm
from repro.sharding import planner
from repro.train import optimizer as opt_lib, step as step_lib

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


# ---------------------------------------------------------------------------
# abstract inputs + shardings per cell
# ---------------------------------------------------------------------------


def _abstract_cell(cfg, shape, plan, *, with_opt, param_dtype=None):
    import jax.numpy as _jnp
    if param_dtype is None:
        param_dtype = _jnp.float32
    aparams = lm.abstract_params(cfg, dtype=param_dtype)
    pspecs = planner.param_specs(cfg, aparams, plan)
    specs = lm.input_specs(cfg, shape)
    out = {"params": (aparams, pspecs)}
    if shape.mode == "decode":
        sspecs = planner.decode_state_specs(cfg, plan, specs["state"])
        tspec = planner.batch_specs(cfg, shape, plan, specs["tokens"]) \
            if plan.decode_batch_shard else jax.tree.map(
                lambda l: jax.sharding.PartitionSpec(
                    *([None] * len(l.shape))), specs["tokens"])
        out["tokens"] = (specs["tokens"], tspec)
        out["state"] = (specs["state"], sspecs)
    else:
        bspecs = planner.batch_specs(cfg, shape, plan, specs["batch"])
        out["batch"] = (specs["batch"], bspecs)
    if with_opt:
        aopt = jax.eval_shape(opt_lib.init_opt_state, aparams)
        out["opt"] = (aopt, {"m": planner.opt_specs(cfg, aparams, plan),
                             "v": planner.opt_specs(cfg, aparams, plan),
                             "step": jax.sharding.PartitionSpec()})
    return out


def _sh(mesh, spec_tree):
    P = jax.sharding.PartitionSpec
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def lower_cell(cfg, shape, mesh, plan, *, unroll=False, probe=False,
               n_micro=None, param_dtype=None):
    """Lower the cell's step.  probe=True -> fwd+bwd only (train)."""
    n_micro = plan.n_micro if n_micro is None else n_micro
    ab = _abstract_cell(cfg, shape, plan, with_opt=(shape.mode == "train"
                                                    and not probe),
                        param_dtype=param_dtype)
    P = jax.sharding.PartitionSpec
    repl = jax.sharding.NamedSharding(mesh, P())
    pshard = _sh(mesh, ab["params"][1])

    if shape.mode == "train":
        opt_cfg = opt_lib.AdamWConfig()
        if probe:
            def probe_step(params, batch):
                loss, grads = jax.value_and_grad(
                    lambda p: lm.forward_train(p, batch, cfg, remat=True,
                                               unroll=unroll))(params)
                return grads
            bshard = _sh(mesh, ab["batch"][1])
            fn = jax.jit(probe_step, in_shardings=(pshard, bshard),
                         out_shardings=pshard)
            with mesh:
                return fn.lower(ab["params"][0], ab["batch"][0])
        step = step_lib.make_train_step(cfg, opt_cfg, n_micro=n_micro)
        oshard = _sh(mesh, ab["opt"][1])
        bshard = _sh(mesh, ab["batch"][1])
        metr = {"grad_norm": repl, "lr": repl, "loss": repl}
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, metr),
                     donate_argnums=(0, 1))
        with mesh:
            return fn.lower(ab["params"][0], ab["opt"][0], ab["batch"][0])

    if shape.mode == "prefill":
        def prefill(params, batch):
            return lm.forward_prefill(params, batch, cfg, unroll=unroll)
        bshard = _sh(mesh, ab["batch"][1])
        # state shardings: infer from abstract output specs
        out_state = jax.eval_shape(prefill, ab["params"][0], ab["batch"][0])
        sspecs = planner.decode_state_specs(cfg, plan, out_state[1])
        fn = jax.jit(prefill, in_shardings=(pshard, bshard),
                     out_shardings=(repl, _sh(mesh, sspecs)))
        with mesh:
            return fn.lower(ab["params"][0], ab["batch"][0])

    # decode
    def decode(params, tokens, state):
        return lm.forward_decode(params, tokens, state, cfg, unroll=unroll)
    tshard = _sh(mesh, ab["tokens"][1])
    sshard = _sh(mesh, ab["state"][1])
    fn = jax.jit(decode, in_shardings=(pshard, tshard, sshard),
                 out_shardings=(repl, sshard), donate_argnums=(2,))
    with mesh:
        return fn.lower(ab["params"][0], ab["tokens"][0], ab["state"][0])


def _probe_cfg(cfg, depth_groups):
    """Config truncated to ``depth_groups`` layer groups (for cost probes)."""
    import dataclasses as dc
    from repro.models import blocks
    gs = blocks.group_size(cfg)
    changes = {"n_layers": gs * depth_groups,
               "name": f"{cfg.name}-probe{depth_groups}"}
    if cfg.is_encoder_decoder:
        changes["n_enc_layers"] = depth_groups
    return dc.replace(cfg, **changes)


def _analyze(compiled, n_chips):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    cost = dict(cost)
    coll = hlo_analysis.collective_stats(compiled.as_text())
    mem = compiled.memory_analysis()
    memd = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        memd[f] = getattr(mem, f, None)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": coll.total_bytes,
        "coll_counts": coll.counts,
        "coll_by_kind": coll.bytes_by_kind,
        "memory": memd,
    }


def _local_param_bytes(cfg, plan, mesh):
    aparams = lm.abstract_params(cfg)
    pspecs = planner.param_specs(cfg, aparams, plan)
    total = 0
    for leaf, spec in zip(jax.tree.leaves(aparams),
                          jax.tree.leaves(
                              pspecs, is_leaf=lambda x: isinstance(
                                  x, jax.sharding.PartitionSpec))):
        sh = jax.sharding.NamedSharding(mesh, spec)
        shard_shape = sh.shard_shape(leaf.shape)
        n = 1
        for dsz in shard_shape:
            n *= dsz
        total += n * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, probes=True,
             out_dir: Path = OUT_DIR, force=False, plan_overrides=None,
             tag="baseline", serve_bf16=False, moe_scan=False,
             moe_local=False):
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}__{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = cfgbase.get_config(arch)
    shape = cfgbase.SHAPES_BY_NAME[shape_name]
    enabled, why = cfgbase.cell_enabled(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
           "timestamp": time.time()}
    if not enabled:
        rec.update(status="skipped", reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    plan = planner.make_plan(cfg, shape, mesh, **(plan_overrides or {}))
    rec["plan"] = {"fsdp": plan.fsdp, "n_micro": plan.n_micro,
                   "data_axes": plan.data_axes,
                   "n_chips": plan.n_chips,
                   "cache_seq_model": plan.cache_seq_model,
                   "decode_batch_shard": plan.decode_batch_shard,
                   "serve_bf16": serve_bf16, "moe_scan": moe_scan,
                   "moe_local": moe_local}
    from repro.models import moe as _moe
    _moe.DISPATCH_SCAN = moe_scan
    _moe.DISPATCH_GROUPS = plan.data_size if moe_local else 0
    _moe.GROUP_AXES = tuple(plan.data_axes)
    _moe.MESH = mesh if moe_local else None
    pdtype = (jnp.bfloat16 if serve_bf16 and shape.mode != "train"
              else jnp.float32)
    try:
        t0 = time.time()
        lowered = lower_cell(cfg, shape, mesh, plan, param_dtype=pdtype)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        rec["real"] = _analyze(compiled, plan.n_chips)
        rec["lower_s"] = round(t1 - t0, 2)
        rec["compile_s"] = round(t2 - t1, 2)
        del compiled, lowered
    except Exception as e:  # a failing cell is a bug: record it loudly
        rec.update(status="FAILED", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    if probes:
        try:
            rec["probe"] = _run_probes(cfg, shape, mesh, plan,
                                       param_dtype=pdtype)
        except Exception as e:
            rec["probe_error"] = f"{type(e).__name__}: {e}"

    rec["derived"] = _derive_roofline(cfg, shape, mesh, plan, rec)
    rec["status"] = "ok"
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def _run_probes(cfg, shape, mesh, plan, param_dtype=None):
    """Unrolled depth-1/2 probes under the real shardings."""
    from repro.models import blocks
    out = {}
    for d in (1, 2):
        pcfg = _probe_cfg(cfg, d)
        pshape = shape
        if shape.mode == "train":
            # probe one microbatch
            pshape = dataclasses.replace(
                shape, global_batch=max(shape.global_batch // plan.n_micro,
                                        1))
        pplan = dataclasses.replace(plan, n_micro=1)
        lowered = lower_cell(pcfg, pshape, mesh, pplan, unroll=True,
                             probe=(shape.mode == "train"),
                             param_dtype=param_dtype)
        compiled = lowered.compile()
        out[f"d{d}"] = _analyze(compiled, plan.n_chips)
        del compiled, lowered
    return out


def _derive_roofline(cfg, shape, mesh, plan, rec):
    """Combine probes + analytic optimizer into per-device roofline terms."""
    from repro.models import blocks
    ng = cfg.n_layers // blocks.group_size(cfg)
    n_chips = plan.n_chips
    if "probe" in rec:
        d1, d2 = rec["probe"]["d1"], rec["probe"]["d2"]
        terms = {}
        for key in ("flops", "bytes", "coll_bytes"):
            B = max(d2[key] - d1[key], 0.0)
            A = max(d1[key] - B, 0.0)
            tot = A + ng * B
            if shape.mode == "train":
                tot *= plan.n_micro
            terms[key] = tot
        if shape.mode == "train":
            # analytic AdamW: read p/m/v/g + write p/m/v (fp32), ~12 flop/p
            pl_bytes = _local_param_bytes(cfg, plan, mesh)
            terms["bytes"] += 7 * pl_bytes
            terms["flops"] += 3 * pl_bytes  # 12 flops per 4-byte param
            # grad sync was inside every probe; real pipeline syncs once
            if plan.n_micro > 1:
                dsz = plan.data_size
                gsync = 2 * (1 - 1 / dsz) * pl_bytes
                terms["coll_bytes"] -= (plan.n_micro - 1) * gsync
                terms["coll_bytes"] = max(terms["coll_bytes"], 0.0)
        method = "probe"
    else:
        terms = {k: rec["real"][k] for k in ("flops", "bytes", "coll_bytes")}
        method = "real(while-body-once; underestimates scans)"

    t_c = terms["flops"] / mesh_lib.PEAK_FLOPS_BF16
    t_m = terms["bytes"] / mesh_lib.HBM_BW
    t_x = terms["coll_bytes"] / mesh_lib.ICI_LINK_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    pc = cfg.param_counts()
    n_active = pc["active"]
    if shape.mode == "train":
        model_flops = 6 * n_active * shape.tokens
    elif shape.mode == "prefill":
        model_flops = 2 * n_active * shape.tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    hlo_flops_global = terms["flops"] * n_chips
    return {
        "method": method,
        "flops_per_device": terms["flops"],
        "hbm_bytes_per_device": terms["bytes"],
        "coll_bytes_per_device": terms["coll_bytes"],
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_x,
        "dominant": dom,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "roofline_bound_s": max(t_c, t_m, t_x),
        "roofline_fraction": (t_c / max(t_c, t_m, t_x)
                              if max(t_c, t_m, t_x) > 0 else 0.0),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def force_placeholder_devices(n: int = 512):
    """The dry-run builds the production meshes (16x16 single-pod,
    2x16x16 multi-pod) out of host placeholder devices.  MUST run before
    jax initialises its backend — main() calls it first thing, BEFORE
    any jax array op.  Deliberately NOT a module-level side effect:
    importing this module (tests, tooling) must never change the device
    topology of the importing process."""
    import jax
    backends = getattr(getattr(jax._src, "xla_bridge", None),
                       "_backends", None)
    if backends:  # backend already up: too late
        raise RuntimeError(
            "force_placeholder_devices must run before jax init")
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + f" --xla_force_host_platform_device_count={n}")


def main():
    force_placeholder_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--serve-bf16", action="store_true",
                    help="bf16 params for prefill/decode cells")
    ap.add_argument("--moe-scan", action="store_true",
                    help="associative-scan MoE dispatch")
    ap.add_argument("--moe-local", action="store_true",
                    help="group-local MoE dispatch (no token exchange)")
    ap.add_argument("--fsdp", default="auto", choices=("auto", "on", "off"))
    ap.add_argument("--cache-seq-model", action="store_true",
                    help="shard decode KV cache length over model axis")
    ap.add_argument("--no-decode-batch-shard", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    args = ap.parse_args()
    plan_overrides = {"cache_seq_model": args.cache_seq_model,
                      "decode_batch_shard": not args.no_decode_batch_shard}
    if args.fsdp != "auto":
        plan_overrides["fsdp"] = args.fsdp == "on"
    if args.n_micro:
        plan_overrides["n_micro"] = args.n_micro

    archs = ([args.arch] if args.arch
             else sorted(cfgbase.all_configs().keys()))
    shapes = ([args.shape] if args.shape
              else [s.name for s in cfgbase.SHAPES])
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    results = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                t0 = time.time()
                rec = run_cell(a, s, m, probes=not args.no_probes,
                               out_dir=Path(args.out), force=args.force,
                               tag=args.tag, serve_bf16=args.serve_bf16,
                               moe_scan=args.moe_scan,
                               moe_local=args.moe_local,
                               plan_overrides=plan_overrides)
                dt = time.time() - t0
                st = rec.get("status", "?")
                dom = rec.get("derived", {}).get("dominant", "-")
                print(f"[{st:8s}] {a:28s} {s:12s} {m:6s} dom={dom:10s} "
                      f"({dt:.1f}s)", flush=True)
                if st == "FAILED":
                    print("    " + rec.get("error", ""), flush=True)
                results.append(rec)
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skipped" for r in results)
    n_fail = sum(r.get("status") == "FAILED" for r in results)
    print(f"\ndry-run cells: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
