"""Post-SPMD HLO analysis: collective-traffic accounting + roofline terms.

``compiled.cost_analysis()`` gives FLOPs and HBM bytes but no collective
traffic, so we parse ``compiled.as_text()`` (per-partition shapes) and sum
operand sizes of every collective, weighted by the ring-algorithm transfer
factor for its group size ``n``:

    all-reduce        2 (n-1)/n  x bytes     (reduce-scatter + all-gather)
    all-gather          (n-1)/n  x out bytes
    reduce-scatter      (n-1)/n  x in bytes
    all-to-all          (n-1)/n  x bytes
    collective-permute          1 x bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+\[[0-9,]*\][^ ]*|\([^)]*\))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]  # ring-transfer bytes per device
    raw_bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    xfer: Dict[str, float] = {}
    raw: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # async pair: count only the -start
        size = _shape_bytes(out_shape)
        # group size n
        n = 0
        g = _GROUPS_RE.search(line)
        if g:
            n = int(g.group(2))
        else:
            ge = _GROUPS_EXPLICIT_RE.search(line)
            if ge:
                n = len(ge.group(1).split(","))
        n = max(n, 2)
        if kind == "all-reduce":
            factor, base = 2 * (n - 1) / n, size
        elif kind == "all-gather":
            factor, base = (n - 1) / n, size  # output = gathered size
        elif kind == "reduce-scatter":
            # output is the shard; input ~= shard * n
            factor, base = (n - 1) / n, size * n
        elif kind == "all-to-all":
            factor, base = (n - 1) / n, size
        else:  # collective-permute
            factor, base = 1.0, size
        counts[kind] = counts.get(kind, 0) + 1
        xfer[kind] = xfer.get(kind, 0.0) + factor * base
        raw[kind] = raw.get(kind, 0.0) + float(base)
    return CollectiveStats(counts, xfer, raw)


def roofline_terms(cost: dict, coll: CollectiveStats, n_chips: int,
                   *, peak_flops: float, hbm_bw: float, link_bw: float,
                   ici_links: int = 1) -> dict:
    """Seconds per step for each roofline term.

    cost_analysis() FLOPs/bytes on a post-SPMD module are per-partition on
    the CPU backend (the module IS the per-device program); collective
    bytes from the HLO are per-device already.
    """
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / peak_flops
    t_memory = bytes_hbm / hbm_bw
    t_coll = coll.total_bytes / (link_bw * max(ici_links, 1))
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {
        "flops_per_device": flops,
        "hbm_bytes_per_device": bytes_hbm,
        "collective_bytes_per_device": coll.total_bytes,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "collective_counts": coll.counts,
        "collective_bytes_by_kind": coll.bytes_by_kind,
    }
