"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """jax.make_mesh across jax versions: AxisType (and the axis_types
    kwarg) only exist on newer releases; older ones default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_detection_mesh(devices=None):
    """1-D data-parallel mesh over the local devices for the detection
    pipeline's sharded ``run_batch`` (batch dim sharded on ``data``,
    everything else replicated)."""
    import numpy as np
    devs = list(devices) if devices is not None else jax.devices()
    return jax.sharding.Mesh(np.array(devs), ("data",))


def make_local_mesh(model: int = 1):
    """Whatever this host has (CPU smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return _mesh((data, model), ("data", "model"))


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_LINK_BW = 50e9            # bytes/s per link
