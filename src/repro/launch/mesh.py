"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run must
set XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (one v5e pod, 256 chips) or 2x16x16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Whatever this host has (CPU smoke tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_LINK_BW = 50e9            # bytes/s per link
