"""Serving launcher: batched watermark-detection service + LM decode
service, driven by QRMark's adaptive allocator and LPT scheduler.

Two serving regimes:

* **offline** (:class:`DetectionService`) — a stream of image batches
  known up front -> ingest/tile/decode/RS with lanes allocated by
  Algorithm 1 (``allocator.assign``) and executed as real concurrency
  by the :class:`repro.core.lanes.LaneExecutor`; mini-batches are
  scheduled by Algorithm 2 with straggler mitigation.  Ragged batches
  are padded up to a shape bucket (bounding jit recompilation) and
  sliced back — per-image RNG keys make pad rows inert.
* **online** (``--online``, :class:`repro.serving.DetectionServer`) —
  per-request submissions arriving over time through an open-loop
  Poisson load generator (:func:`open_loop_load`): dynamic
  micro-batching, SLO-tiered admission control (``--classes`` /
  ``--bulk-frac``), content-addressed result caching
  (``--cache-exact`` / ``--cache-embed-threshold``) with an optional
  Zipf repeat-heavy workload (``--zipf`` / ``--pool``), and
  per-request / per-class latency percentiles.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Callable, Dict, Iterable, List, Optional, \
    Tuple  # noqa: F401

import jax
import numpy as np

from repro.core import allocator, scheduler as sched_lib
from repro.core.detect import DetectionConfig, DetectionPipeline, \
    STAGE_NAMES
from repro.data import pipeline as data_lib
# pad_to_bucket moved to the serving layer (the batcher shapes its
# micro-batches with it); re-exported here for existing callers
from repro.serving.batcher import AdmissionError, pad_to_bucket  # noqa: F401


@dataclasses.dataclass
class ServiceReport:
    images: int
    wall_s: float
    throughput_ips: float
    allocation: Optional[List[int]]
    lanes: Optional[Dict[str, int]]
    lane_loads: Optional[List[float]]
    straggler_retries: int = 0


class DetectionService:
    """Adaptive, scheduled batch-stream detection service (the offline
    regime; the request-level online runtime is
    :class:`repro.serving.DetectionServer`, ``--online``)."""

    def __init__(self, det_cfg: DetectionConfig, extractor_params, *,
                 lane_budget: int = 8, mem_cap: float = 2e9,
                 lanes: int = 0, pad_bucket: int = 0):
        self.pipe = DetectionPipeline(det_cfg, extractor_params)
        self.det_cfg = det_cfg
        self.lane_budget = lane_budget
        self.mem_cap = mem_cap
        self.pad_bucket = pad_bucket
        self.allocation: Optional[allocator.Allocation] = None
        # lanes knob: 0 = adaptive (allocator.assign after warmup),
        # n >= 1 = fixed n decode/RS lanes, bypassing the allocator
        self.lanes: Optional[Dict[str, int]] = (
            None if lanes == 0 else
            {"ingest": 1, "decode": max(1, lanes), "rs": max(1, lanes)})
        self._fixed_lanes = lanes != 0
        self.warmup_stats: Dict[int, tuple] = {}

    # -- Algorithm 1: warm-up profiling + adaptive allocation -------------
    def warmup(self, sample_raw):
        """Profile the pipeline's actual stage functions (tile-first
        ingest produces the decode input directly; staged ingest the
        full preprocessed image; decode is the fused Pallas kernel when
        configured) and run Algorithm 1.

        Every stage is profiled through the engine the pipeline will
        really run — in particular RS goes through ``_rs_correct`` (the
        on-device batched decoder when ``rs_mode="device"``, the CPU
        pool or sync loop otherwise), not a host-side reference loop, so
        the lane allocation matches what serving executes."""
        cfg = self.det_cfg
        key = jax.random.key(0)
        pre = allocator.profile_stage(
            lambda b: jax.block_until_ready(self.pipe._ingest(b, key)),
            sample_raw, name="ingest")
        x, keys = self.pipe._ingest(sample_raw, key)
        dec = allocator.profile_stage(
            lambda b: jax.block_until_ready(
                self.pipe._decode_x(b, keys[: b.shape[0]])),
            x, name="decode")
        logits = self.pipe._decode_x(x, keys)
        bits = self.pipe._bits(logits)
        rs_sample = bits if cfg.rs_mode == "device" else np.asarray(bits)
        rs_prof = allocator.profile_stage(
            lambda bb: jax.block_until_ready(self.pipe._rs_correct(bb)),
            rs_sample, name="rs")
        profiles = [pre, dec, rs_prof]
        self.allocation = allocator.adaptive_allocation(
            profiles, global_batch=sample_raw.shape[0],
            stream_budget=self.lane_budget, mem_cap=self.mem_cap)
        if not self._fixed_lanes:
            self.lanes = allocator.assign(
                profiles, global_batch=sample_raw.shape[0],
                lane_budget=self.lane_budget, mem_cap=self.mem_cap)
        self.warmup_stats[cfg.tile] = (dec.t_per_sample, dec.u_per_sample)
        return self.allocation

    # -- Algorithm 2 + lane-executor streaming -----------------------------
    def serve(self, batches: Iterable, *,
              use_scheduler: bool = True) -> ServiceReport:
        """Run a stream of (possibly ragged) batches through the lane
        executor.  With the scheduler on, each request batch is split
        into LPT-placed mini-batch tasks first (Algorithm 2); the task
        slices then flow through the executor as the work stream."""
        mon = sched_lib.StragglerMonitor()
        lane_loads: Optional[List[float]] = None
        work: List[Tuple[np.ndarray, int]] = []  # (padded slice, true b)
        for raw in batches:
            raw = np.asarray(raw)
            b = raw.shape[0]
            if use_scheduler and self.warmup_stats:
                tasks = sched_lib.build_tasks(
                    [{"i": i} for i in range(b)], self.warmup_stats,
                    b0=b, select_tile=lambda m: self.det_cfg.tile,
                    group=max(1, b // 4))
                n_lanes = (sum(self.lanes.values()) if self.lanes else 4)
                sched = sched_lib.lpt_schedule(
                    tasks, n_lanes=max(n_lanes, 1), balance_slack=0.25,
                    mem_cap=self.mem_cap, b_min=1, global_batch=b)
                # accumulate the LPT per-lane predicted loads across
                # request batches — the report's lane_loads field
                if lane_loads is None:
                    lane_loads = [0.0] * len(sched.loads)
                lane_loads = [a + l for a, l in zip(lane_loads,
                                                    sched.loads)]
                off = 0
                for lane in sched.lanes:
                    for task in lane:
                        sl = raw[off: off + task.n_samples]
                        off += task.n_samples
                        if sl.shape[0]:
                            work.append(pad_to_bucket(sl, self.pad_bucket))
            else:
                work.append(pad_to_bucket(raw, self.pad_bucket))

        def feed():
            for tid, (sl, tb) in enumerate(work):
                mon.start(tid)
                # (padded slice, true size): pad rows stay escalation-
                # inert and consume() slices them off the results
                yield (sl, tb)

        n_img_box = [0]

        def consume(tid: int, res: dict):
            # completion is recorded HERE, as each result comes off the
            # executor — recording it after the whole stream finished
            # (the old zip loop) made every per-task latency the total
            # stream wall time, useless for straggler timeouts
            true_b = work[tid][1]
            for k, v in res.items():
                if getattr(v, "ndim", 0) >= 1:
                    res[k] = v[:true_b]   # slice pad rows off
            n_img_box[0] += true_b
            mon.complete(tid)

        t0 = time.perf_counter()
        out = self.pipe.run_stream(feed(), lanes=self.lanes,
                                   on_result=consume)
        wall = time.perf_counter() - t0
        n_img = n_img_box[0]
        return ServiceReport(
            images=n_img, wall_s=wall,
            throughput_ips=n_img / wall if wall else 0.0,
            allocation=(self.allocation.streams if self.allocation
                        else None),
            lanes=out.get("lanes"),
            lane_loads=([round(l, 6) for l in lane_loads]
                        if lane_loads else None),
            # speculative re-executions the monitor actually recorded
            # (mark_retried) — not sink-side duplicate completions,
            # which the in-order executor can never produce
            straggler_retries=mon.retry_count)

    # -- data-parallel sharded path ----------------------------------------
    def serve_sharded(self, batches: Iterable) -> ServiceReport:
        """Shard each batch across every local device (1-D data mesh)
        instead of pipelining — the multi-chip scaling axis; combine
        with lanes by running one service per host."""
        from repro.launch.mesh import make_detection_mesh
        mesh = make_detection_mesh()
        n_img = 0
        t0 = time.perf_counter()
        for raw in batches:
            out = self.pipe.run_batch(np.asarray(raw), mesh=mesh)
            n_img += out["ok"].shape[0]
        wall = time.perf_counter() - t0
        return ServiceReport(
            images=n_img, wall_s=wall,
            throughput_ips=n_img / wall if wall else 0.0,
            allocation=None, lanes=None, lane_loads=None)


def open_loop_load(server, *, qps: float, duration_s: float,
                   make_images: Callable[[int], np.ndarray],
                   seed: int = 0,
                   priority: Optional[Callable[[int],
                                               Optional[str]]] = None
                   ) -> dict:
    """Open-loop Poisson load generator (the online serving regime).

    Request k arrives at exponential inter-arrival gaps of mean
    ``1/qps`` **regardless of completions** — unlike closed-loop
    drivers, queueing delay is exposed instead of self-throttled, so
    latency percentiles vs offered load mean something.  Rejected
    submissions (admission backpressure) are counted, not retried —
    and counted *separately* from execution failures, which surface
    later through the handles.  ``priority`` maps request index ->
    admission class (None = the server's highest class).

    Returns {handles, offered, rejected, wall_s}; call
    ``server.stats()`` after draining for the latency/throughput view.
    """
    rng = np.random.default_rng(seed)
    handles = []
    rejected = 0
    t0 = time.perf_counter()
    t_next = t0
    k = 0
    while t_next - t0 < duration_s:
        now = time.perf_counter()
        if now < t_next:
            time.sleep(t_next - now)
        try:
            handles.append(server.submit(
                make_images(k),
                priority=priority(k) if priority else None))
        except AdmissionError:
            rejected += 1
        k += 1
        t_next += rng.exponential(1.0 / qps)
    return {"handles": handles, "offered": k, "rejected": rejected,
            "wall_s": time.perf_counter() - t0}


def _lat_ms(dist: dict) -> dict:
    return {k: round(dist.get(k, float("nan")) * 1e3, 2)
            for k in ("p50", "p95", "p99", "mean")}


def run_online(cfg: DetectionConfig, params, *, qps: float,
               duration_s: float, raw_size: int, group: int = 1,
               max_batch: int = 16, max_wait_ms: float = 10.0,
               max_queue: int = 256, lanes: int = 0,
               realloc_every: int = 0, seed: int = 0,
               classes: Optional[Dict[str, float]] = None,
               bulk_frac: float = 0.0, zipf: float = 0.0,
               pool: int = 0, quiet: bool = False) -> dict:
    """Build a :class:`~repro.serving.DetectionServer`, warm it up,
    drive it with Poisson arrivals, drain, and report.

    ``classes`` enables SLO-tiered admission ({name: deadline_ms},
    first = highest priority); ``bulk_frac`` of requests are then sent
    as the *lowest* class.  ``pool`` > 0 draws each request's images
    from a fixed pool of ``pool`` synthetic images — uniformly, or
    Zipf-skewed with exponent ``zipf`` > 1 — the repeat-heavy
    workload the content cache is for."""
    from repro.serving import BatcherConfig, DetectionServer
    lane_map = (None if lanes == 0 else
                {"ingest": 1, "decode": max(1, lanes),
                 "rs": max(1, lanes)})
    srv = DetectionServer(
        cfg, params,
        batcher=BatcherConfig(max_batch=max_batch,
                              max_wait_ms=max_wait_ms,
                              max_queue=max_queue, classes=classes),
        lanes=lane_map, realloc_every=realloc_every)
    buckets = srv.warmup(data_lib.synth_image(0, raw_size))
    if not quiet:
        print(f"online: warmed buckets {buckets}, lanes "
              f"{srv.lane_counts()}", flush=True)
    srv.start()
    srv.metrics.reset()

    wl_rng = np.random.default_rng(seed + 1)  # workload draws, not
    #                                           arrival gaps

    def pool_index(k: int) -> int:
        if pool <= 0:
            return k
        if zipf > 1.0:
            return int((wl_rng.zipf(zipf) - 1) % pool)
        return int(wl_rng.integers(pool))

    def make_images(k: int) -> np.ndarray:
        base = pool_index(k)
        return np.stack([data_lib.synth_image(1000 + base * group + i,
                                              raw_size)
                         for i in range(group)])

    priority = None
    if classes and bulk_frac > 0.0:
        names = list(classes)

        def priority(k: int) -> str:
            return (names[-1] if wl_rng.random() < bulk_frac
                    else names[0])

    load = open_loop_load(srv, qps=qps, duration_s=duration_s,
                          make_images=make_images, seed=seed,
                          priority=priority)
    srv.drain(timeout=120.0)
    stats = srv.stats()
    srv.close()
    failed = int(stats["counters"].get("requests_failed", 0))
    report = {
        "qps_offered": qps, "duration_s": duration_s, "group": group,
        "offered": load["offered"],
        # rejected (admission backpressure) and failed (execution
        # errors) are different outcomes — never folded together
        "rejected": load["rejected"],
        "rejection_rate": round(stats["rejection_rate"], 4),
        "failed": failed,
        "completed": int(stats["counters"].get("requests_completed", 0)),
        "throughput_rps": round(stats["throughput_rps"], 2),
        "throughput_ips": round(stats["throughput_ips"], 2),
        "latency_ms": _lat_ms(stats.get("request_latency_s", {})),
        "batch_occupancy": round(
            stats.get("batch_occupancy", {}).get("mean", float("nan")),
            3),
        "queue_depth_last": stats["gauges"].get("queue_depth", 0),
        "lanes": stats["lanes"],
        "straggler_retries": stats["straggler_retries"],
    }
    if classes:
        report["latency_ms_by_class"] = {
            c: _lat_ms(stats.get(f"request_latency_{c}_s", {}))
            for c in classes}
    if getattr(cfg, "cache_exact", False) or \
            getattr(cfg, "cache_embedding_threshold", 0.0) > 0:
        report["cache"] = {
            "hit_exact": stats["cache_hit_exact"],
            "hit_embed": stats["cache_hit_embed"],
            "miss": stats["cache_miss"],
            "dedup_coalesced": stats["dedup_coalesced"],
            "hit_rate": round(stats["cache_hit_rate"], 4),
        }
    if srv.registry.policy.enabled:
        report["escalation_rate"] = round(stats["escalation_rate"], 4)
        report["escalation_batches"] = stats["escalation_batches"]
        report["mean_tiles_per_image"] = round(
            stats.get("tiles_per_image", {}).get("mean", 1.0), 3)
    return report


def run_fleet(cfg: DetectionConfig, params, *, replicas: int,
              qps: float, duration_s: float, raw_size: int,
              group: int = 1, max_batch: int = 16,
              max_wait_ms: float = 10.0, max_queue: int = 256,
              lanes: int = 0, seed: int = 0, pin_devices: bool = True,
              fault_plans: Optional[dict] = None,
              quiet: bool = False) -> dict:
    """Build a :class:`~repro.serving.FleetRouter` over ``replicas``
    :class:`~repro.serving.Replica` instances, warm them, drive the
    fleet with Poisson arrivals THROUGH the router, drain, and report.

    ``pin_devices`` assigns replica *i* to local jax device ``i % D``
    — with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this
    is the CI-scale fleet simulation (one forced CPU device per
    replica); on a single device it is a no-op.  Requests route by
    content digest, so results are bit-identical to a single server at
    any fleet size.

    ``fault_plans`` maps replica name (``r0``..) to a
    :class:`~repro.serving.FaultPlan` — the fig14 chaos arm
    (kill-one-replica-mid-run) is this driver plus one plan entry, not
    a separate code path."""
    from repro.serving import BatcherConfig, FleetRouter, Replica
    devices = jax.local_devices()
    lane_map = (None if lanes == 0 else
                {"ingest": 1, "decode": max(1, lanes),
                 "rs": max(1, lanes)})
    reps = [Replica(
        f"r{i}", cfg, params,
        batcher=BatcherConfig(max_batch=max_batch,
                              max_wait_ms=max_wait_ms,
                              max_queue=max_queue),
        lanes=lane_map,
        fault_plan=(fault_plans or {}).get(f"r{i}"),
        device=(devices[i % len(devices)] if pin_devices else None))
        for i in range(replicas)]
    router = FleetRouter(reps)
    router.warmup(data_lib.synth_image(0, raw_size))
    router.start()
    if not quiet:
        print(f"fleet: {replicas} replicas over {len(devices)} "
              f"device(s), warmed", flush=True)
    router.metrics.reset()

    def make_images(k: int) -> np.ndarray:
        return np.stack([data_lib.synth_image(1000 + k * group + i,
                                              raw_size)
                         for i in range(group)])

    load = open_loop_load(router, qps=qps, duration_s=duration_s,
                          make_images=make_images, seed=seed)
    drained = router.drain(timeout=120.0)
    stats = router.stats()
    unresolved = sum(not h.done() for h in load["handles"])
    router.close()
    lat = stats.get("request_latency_s", {})
    return {
        "replicas": replicas, "qps_offered": qps,
        "duration_s": duration_s, "group": group,
        "offered": load["offered"], "rejected": load["rejected"],
        "completed": int(stats["counters"].get("requests_completed", 0)),
        "failed": int(stats["counters"].get("requests_failed", 0)),
        "unresolved": int(unresolved), "drained": bool(drained),
        "throughput_rps": round(stats["throughput_rps"], 2),
        "latency_ms": _lat_ms(lat),
        "spillovers": stats["spillovers"],
        "reroutes": stats["reroutes"],
        "unhealthy": stats["unhealthy"],
        "straggler_retries": stats["straggler_retries"],
        "faults_injected": int(
            stats["fleet_counters"].get("faults_injected", 0)),
        "replica_table": stats["replicas"],
    }


def enable_compilation_cache(path: str, *, min_entry_bytes: int = 0,
                             min_compile_secs: float = 0.0) -> bool:
    """Point jax's persistent compilation cache at ``path`` so a service
    restart reuses every jitted detection graph (ingest/decode/RS and
    the fused fast path) instead of recompiling — the jit warm-up is the
    dominant cold-start cost for a serving replica.  Returns False when
    this jax build has no persistent cache (knob is then a no-op)."""
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          min_entry_bytes)
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          min_compile_secs)
        return True
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--img", type=int, default=128)
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--mode", default="qrmark")
    ap.add_argument("--rs-mode", default="device",
                    choices=("device", "cpu_pool", "cpu_sync"))
    ap.add_argument("--lanes", type=int, default=0,
                    help="0 = adaptive (Algorithm 1); n = fixed n "
                         "decode/RS lanes")
    ap.add_argument("--ragged", action="store_true",
                    help="send odd-size batches to exercise padding")
    ap.add_argument("--sharded", action="store_true",
                    help="data-parallel run_batch over all local devices")
    ap.add_argument("--staged-ingest", action="store_true",
                    help="disable tile-first ingest (full-image "
                         "preprocess + tile select in decode)")
    ap.add_argument("--decode-dtype", default="fp32",
                    choices=("fp32", "bf16", "int8"),
                    help="fused-decode precision policy: fp32 = "
                         "bit-exact vs the unfused extractor, bf16 = "
                         "MXU compute with fp32 accumulation, int8 = "
                         "per-channel-quantized weights with int32 "
                         "accumulation (RS absorbs the extra bit "
                         "noise)")
    ap.add_argument("--schedule", default="flat",
                    help="decode kernel schedule: 'flat' (one image "
                         "per grid step), 'auto' (winner from the "
                         "autotune cache), or an explicit "
                         "'bb<N>-ct<N>[-db]' point")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep blocked decode schedules for this "
                         "config before building the service, persist "
                         "the winner in the autotune cache, and serve "
                         "with it (implies --schedule auto)")
    ap.add_argument("--autotune-cache", default="",
                    help="schedule-cache JSON path (default: "
                         "decode_schedules.json next to "
                         "--compilation-cache when given, else "
                         "experiments/autotune/decode_schedules.json)")
    ap.add_argument("--unfused-decode", action="store_true",
                    help="disable the fused Pallas extractor kernel "
                         "(decode runs the unfused XLA graph; warmup "
                         "then profiles and allocates lanes for that)")
    ap.add_argument("--compilation-cache", default="",
                    help="directory for jax's persistent compilation "
                         "cache (reused across service restarts)")
    ap.add_argument("--online", action="store_true",
                    help="request-level serving: DetectionServer + "
                         "open-loop Poisson load instead of the "
                         "offline batch-stream service")
    ap.add_argument("--fleet", action="store_true",
                    help="front --replicas DetectionServer replicas "
                         "with the FleetRouter (rendezvous content "
                         "routing, spill-over, crash re-execution) and "
                         "drive Poisson load through the router; "
                         "implies the online regime")
    ap.add_argument("--replicas", type=int, default=2,
                    help="fleet size for --fleet (replica i pins to "
                         "local device i %% D — force a multi-device "
                         "CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N "
                         "for CI-scale fleet simulation)")
    ap.add_argument("--qps", type=float, default=8.0,
                    help="offered load for --online (requests/s)")
    ap.add_argument("--duration", type=float, default=5.0,
                    help="load-generation window for --online (s)")
    ap.add_argument("--group", type=int, default=1,
                    help="images per request for --online")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="micro-batcher coalescing cap (--online)")
    ap.add_argument("--max-wait-ms", type=float, default=10.0,
                    help="micro-batcher deadline for partial batches")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="admission-control depth bound (images)")
    ap.add_argument("--realloc-every", type=int, default=0,
                    help="re-run Algorithm 1 on measured stage "
                         "latencies every N micro-batches (0 = off)")
    ap.add_argument("--cache-exact", action="store_true",
                    help="tier-1 content-addressed result cache + "
                         "dedup-in-flight (--online); keyless requests "
                         "switch to content-derived fold_in keys so "
                         "hits are bitwise the cold-path result")
    ap.add_argument("--cache-embed-threshold", type=float, default=0.0,
                    help="tier-2 near-duplicate cache cosine threshold "
                         "over the extractor GAP embedding (0 = off; "
                         "approximate — only short-circuits "
                         "escalation rounds)")
    ap.add_argument("--classes", default="",
                    help="SLO admission classes for --online as "
                         "'name:deadline_ms,...', first = highest "
                         "priority (e.g. 'interactive:5,bulk:50'); "
                         "empty = single class at --max-wait-ms")
    ap.add_argument("--bulk-frac", type=float, default=0.0,
                    help="fraction of --online requests submitted as "
                         "the lowest class (requires --classes)")
    ap.add_argument("--zipf", type=float, default=0.0,
                    help="Zipf exponent (> 1) skewing --pool draws — "
                         "the repeat-heavy workload the content cache "
                         "targets (0 = uniform)")
    ap.add_argument("--pool", type=int, default=0,
                    help="draw --online request images from a fixed "
                         "pool of this many distinct synthetic images "
                         "(0 = every request distinct)")
    ap.add_argument("--escalate-tiles", type=int, default=1,
                    help="adaptive escalation tile budget per image "
                         "(1 = single-tile fast path only; k > 1 "
                         "re-decodes RS failures on up to k-1 extra "
                         "tiles, accumulating soft bits)")
    ap.add_argument("--escalate-margin", type=float, default=0.0,
                    help="also escalate images whose mean |logit| is "
                         "below this margin even when RS succeeded "
                         "(0 = RS-failure trigger only; requires "
                         "--escalate-tiles > 1)")
    args = ap.parse_args()

    if args.compilation_cache:
        on = enable_compilation_cache(args.compilation_cache)
        print(f"compilation cache: "
              f"{args.compilation_cache if on else 'unsupported'}")

    from repro.core.extractor import init_extractor, pack_params
    from repro.core.rs.codec import DEFAULT_CODE
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits)

    cache_path = args.autotune_cache
    if not cache_path:
        cache_path = ((args.compilation_cache.rstrip("/")
                       + "/decode_schedules.json")
                      if args.compilation_cache else
                      "experiments/autotune/decode_schedules.json")
    schedule = args.schedule
    if args.autotune:
        # populate (or reuse) the schedule cache before the service is
        # built, so warmup profiles the tuned kernel
        from repro.kernels import autotune as autotune_lib
        autotune_lib.autotune(
            pack_params(params, args.decode_dtype), tile=args.tile,
            batch=args.batch, dtype=args.decode_dtype,
            cache_path=cache_path)
        schedule = "auto"

    cfg = DetectionConfig(tile=args.tile, img_size=args.img,
                          resize_src=args.img + args.img // 8,
                          mode=args.mode, rs_mode=args.rs_mode,
                          tile_first=not args.staged_ingest,
                          fused_decode=not args.unfused_decode,
                          decode_dtype=args.decode_dtype,
                          decode_schedule=schedule,
                          autotune_cache=cache_path,
                          escalate_tiles=args.escalate_tiles,
                          escalate_margin=args.escalate_margin,
                          cache_exact=args.cache_exact,
                          cache_embedding_threshold=(
                              args.cache_embed_threshold))
    if args.fleet:
        if args.replicas < 1:
            raise SystemExit("--replicas must be >= 1")
        rep = run_fleet(cfg, params, replicas=args.replicas,
                        qps=args.qps, duration_s=args.duration,
                        raw_size=args.img + 32, group=args.group,
                        max_batch=args.max_batch,
                        max_wait_ms=args.max_wait_ms,
                        max_queue=args.max_queue, lanes=args.lanes)
        print(json.dumps(rep, indent=1, default=str))
        return
    if args.online:
        classes = None
        if args.classes:
            classes = {}
            for part in args.classes.split(","):
                name, _, ms = part.partition(":")
                classes[name.strip()] = float(ms)
        rep = run_online(cfg, params, qps=args.qps,
                         duration_s=args.duration,
                         raw_size=args.img + 32, group=args.group,
                         max_batch=args.max_batch,
                         max_wait_ms=args.max_wait_ms,
                         max_queue=args.max_queue, lanes=args.lanes,
                         realloc_every=args.realloc_every,
                         classes=classes, bulk_frac=args.bulk_frac,
                         zipf=args.zipf, pool=args.pool)
        print(json.dumps(rep, indent=1))
        return
    svc = DetectionService(cfg, params, lanes=args.lanes)
    sample = np.stack([data_lib.synth_image(i, args.img + 32)
                       for i in range(args.batch)])
    alloc = svc.warmup(sample)
    print(f"allocation: streams={alloc.streams} J*={alloc.bottleneck_s:.4f} "
          f"lanes={svc.lanes}")
    rng = np.random.default_rng(0)
    sizes = [args.batch if not args.ragged else
             int(rng.integers(1, args.batch + 1))
             for _ in range(args.batches)]
    batches = [np.stack([data_lib.synth_image(1000 + k * args.batch + i,
                                              args.img + 32)
                         for i in range(n)])
               for k, n in enumerate(sizes)]
    rep = svc.serve_sharded(batches) if args.sharded else svc.serve(batches)
    print(json.dumps(dataclasses.asdict(rep), indent=1))


if __name__ == "__main__":
    main()
