"""Serving launcher: batched watermark-detection service + LM decode
service, driven by QRMark's adaptive allocator and LPT scheduler.

The detection service is the paper's deployment scenario: a stream of
image batches -> preprocess/tile/decode/RS with lanes allocated by
Algorithm 1 and mini-batches scheduled by Algorithm 2, straggler
mitigation included.  The LM decode service exercises prefill/decode for
the assigned architectures (reduced configs on CPU).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocator, scheduler as sched_lib
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.data import pipeline as data_lib


@dataclasses.dataclass
class ServiceReport:
    images: int
    wall_s: float
    throughput_ips: float
    allocation: Optional[List[int]]
    lane_loads: Optional[List[float]]
    straggler_retries: int = 0


class DetectionService:
    """Adaptive, scheduled detection service (QRMark online stage)."""

    def __init__(self, det_cfg: DetectionConfig, extractor_params, *,
                 lane_budget: int = 8, mem_cap: float = 2e9):
        self.pipe = DetectionPipeline(det_cfg, extractor_params)
        self.det_cfg = det_cfg
        self.lane_budget = lane_budget
        self.mem_cap = mem_cap
        self.allocation: Optional[allocator.Allocation] = None
        self.warmup_stats: Dict[int, tuple] = {}

    # -- Algorithm 1: warm-up profiling + adaptive allocation -------------
    def warmup(self, sample_raw):
        cfg = self.det_cfg
        pre = allocator.profile_stage(
            lambda b: jax.block_until_ready(self.pipe._preprocess(b)),
            sample_raw, name="preprocess")
        x = self.pipe._preprocess(sample_raw)
        key = jax.random.key(0)
        dec = allocator.profile_stage(
            lambda b: jax.block_until_ready(self.pipe._decode(b, key)),
            x, name="decode")
        logits = self.pipe._decode(x, key)
        bits = np.asarray((logits > 0).astype(jnp.int32))

        def rs_stage(bb):
            from repro.core.rs.codec import rs_decode
            return [rs_decode(cfg.code, r) for r in np.asarray(bb)]

        t0 = time.perf_counter()
        rs_stage(bits)
        rs_t = (time.perf_counter() - t0) / bits.shape[0]
        rs_prof = allocator.StageProfile("rs", rs_t, 64.0, 1e-5)
        profiles = [pre, dec, rs_prof]
        self.allocation = allocator.adaptive_allocation(
            profiles, global_batch=sample_raw.shape[0],
            stream_budget=self.lane_budget, mem_cap=self.mem_cap)
        self.warmup_stats[cfg.tile] = (dec.t_per_sample, dec.u_per_sample)
        return self.allocation

    # -- Algorithm 2 + streaming ------------------------------------------
    def serve(self, batches, *, use_scheduler: bool = True) -> ServiceReport:
        mon = sched_lib.StragglerMonitor()
        n_img, retries = 0, 0
        t0 = time.perf_counter()
        for raw in batches:
            b = raw.shape[0]
            if use_scheduler and self.warmup_stats:
                tasks = sched_lib.build_tasks(
                    [{"i": i} for i in range(b)], self.warmup_stats,
                    b0=b, select_tile=lambda m: self.det_cfg.tile,
                    group=max(1, b // 4))
                n_lanes = (sum(self.allocation.streams)
                           if self.allocation else 4)
                sched = sched_lib.lpt_schedule(
                    tasks, n_lanes=max(n_lanes, 1), balance_slack=0.25,
                    mem_cap=self.mem_cap, b_min=1, global_batch=b)
                # execute lane by lane (async dispatch overlaps on device)
                off = 0
                for lane in sched.lanes:
                    for task in lane:
                        mon.start(task.task_id)
                        sl = raw[off: off + task.n_samples]
                        off += task.n_samples
                        if sl.shape[0]:
                            self.pipe.detect_batch(jnp.asarray(sl))
                        if not mon.complete(task.task_id):
                            retries += 1
            else:
                self.pipe.detect_batch(jnp.asarray(raw))
            n_img += b
        wall = time.perf_counter() - t0
        return ServiceReport(
            images=n_img, wall_s=wall,
            throughput_ips=n_img / wall if wall else 0.0,
            allocation=(self.allocation.streams if self.allocation
                        else None),
            lane_loads=None, straggler_retries=retries)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--img", type=int, default=128)
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--mode", default="qrmark")
    args = ap.parse_args()

    from repro.core.extractor import init_extractor
    from repro.core.rs.codec import DEFAULT_CODE
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits)
    cfg = DetectionConfig(tile=args.tile, img_size=args.img,
                          resize_src=args.img + args.img // 8,
                          mode=args.mode)
    svc = DetectionService(cfg, params)
    sample = np.stack([data_lib.synth_image(i, args.img + 32)
                       for i in range(args.batch)])
    alloc = svc.warmup(sample)
    print(f"allocation: streams={alloc.streams} J*={alloc.bottleneck_s:.4f}")
    batches = [np.stack([data_lib.synth_image(1000 + k * args.batch + i,
                                              args.img + 32)
                         for i in range(args.batch)])
               for k in range(args.batches)]
    rep = svc.serve(batches)
    print(json.dumps(dataclasses.asdict(rep), indent=1))


if __name__ == "__main__":
    main()
