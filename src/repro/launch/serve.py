"""Serving launcher: batched watermark-detection service + LM decode
service, driven by QRMark's adaptive allocator and LPT scheduler.

The detection service is the paper's deployment scenario: a stream of
image batches -> ingest/tile/decode/RS with lanes allocated by
Algorithm 1 (``allocator.assign``) and executed as real concurrency by
the :class:`repro.core.lanes.LaneExecutor`; mini-batches are scheduled
by Algorithm 2 with straggler mitigation.  Ragged / odd-size request
batches are padded up to a shape bucket (bounding jit recompilation)
and sliced back — per-image RNG keys make pad rows inert, so padding
never changes a real image's result.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocator, scheduler as sched_lib
from repro.core.detect import DetectionConfig, DetectionPipeline, \
    STAGE_NAMES
from repro.data import pipeline as data_lib


@dataclasses.dataclass
class ServiceReport:
    images: int
    wall_s: float
    throughput_ips: float
    allocation: Optional[List[int]]
    lanes: Optional[Dict[str, int]]
    lane_loads: Optional[List[float]]
    straggler_retries: int = 0


def pad_to_bucket(raw: np.ndarray, bucket: int = 0) -> Tuple[np.ndarray, int]:
    """Pad a ragged batch up to a shape bucket: the next power of two
    when ``bucket`` is 0, else the next multiple of ``bucket``.  Returns
    (padded batch, true size).  Bounded bucket count = bounded number of
    jit compilations no matter what sizes clients send."""
    b = raw.shape[0]
    if bucket > 0:
        target = -(-b // bucket) * bucket
    else:
        target = 1
        while target < b:
            target *= 2
    if target == b:
        return raw, b
    return np.concatenate(
        [raw, np.repeat(raw[-1:], target - b, axis=0)]), b


class DetectionService:
    """Adaptive, scheduled detection service (QRMark online stage)."""

    def __init__(self, det_cfg: DetectionConfig, extractor_params, *,
                 lane_budget: int = 8, mem_cap: float = 2e9,
                 lanes: int = 0, pad_bucket: int = 0):
        self.pipe = DetectionPipeline(det_cfg, extractor_params)
        self.det_cfg = det_cfg
        self.lane_budget = lane_budget
        self.mem_cap = mem_cap
        self.pad_bucket = pad_bucket
        self.allocation: Optional[allocator.Allocation] = None
        # lanes knob: 0 = adaptive (allocator.assign after warmup),
        # n >= 1 = fixed n decode/RS lanes, bypassing the allocator
        self.lanes: Optional[Dict[str, int]] = (
            None if lanes == 0 else
            {"ingest": 1, "decode": max(1, lanes), "rs": max(1, lanes)})
        self._fixed_lanes = lanes != 0
        self.warmup_stats: Dict[int, tuple] = {}

    # -- Algorithm 1: warm-up profiling + adaptive allocation -------------
    def warmup(self, sample_raw):
        """Profile the pipeline's actual stage functions (tile-first
        ingest produces the decode input directly; staged ingest the
        full preprocessed image; decode is the fused Pallas kernel when
        configured) and run Algorithm 1.

        Every stage is profiled through the engine the pipeline will
        really run — in particular RS goes through ``_rs_correct`` (the
        on-device batched decoder when ``rs_mode="device"``, the CPU
        pool or sync loop otherwise), not a host-side reference loop, so
        the lane allocation matches what serving executes."""
        cfg = self.det_cfg
        key = jax.random.key(0)
        pre = allocator.profile_stage(
            lambda b: jax.block_until_ready(self.pipe._ingest(b, key)),
            sample_raw, name="ingest")
        x, keys = self.pipe._ingest(sample_raw, key)
        dec = allocator.profile_stage(
            lambda b: jax.block_until_ready(
                self.pipe._decode_x(b, keys[: b.shape[0]])),
            x, name="decode")
        logits = self.pipe._decode_x(x, keys)
        bits = self.pipe._bits(logits)
        rs_sample = bits if cfg.rs_mode == "device" else np.asarray(bits)
        rs_prof = allocator.profile_stage(
            lambda bb: jax.block_until_ready(self.pipe._rs_correct(bb)),
            rs_sample, name="rs")
        profiles = [pre, dec, rs_prof]
        self.allocation = allocator.adaptive_allocation(
            profiles, global_batch=sample_raw.shape[0],
            stream_budget=self.lane_budget, mem_cap=self.mem_cap)
        if not self._fixed_lanes:
            self.lanes = allocator.assign(
                profiles, global_batch=sample_raw.shape[0],
                lane_budget=self.lane_budget, mem_cap=self.mem_cap)
        self.warmup_stats[cfg.tile] = (dec.t_per_sample, dec.u_per_sample)
        return self.allocation

    # -- Algorithm 2 + lane-executor streaming -----------------------------
    def serve(self, batches: Iterable, *,
              use_scheduler: bool = True) -> ServiceReport:
        """Run a stream of (possibly ragged) batches through the lane
        executor.  With the scheduler on, each request batch is split
        into LPT-placed mini-batch tasks first (Algorithm 2); the task
        slices then flow through the executor as the work stream."""
        mon = sched_lib.StragglerMonitor()
        lane_loads: Optional[List[float]] = None
        work: List[Tuple[np.ndarray, int]] = []  # (padded slice, true b)
        for raw in batches:
            raw = np.asarray(raw)
            b = raw.shape[0]
            if use_scheduler and self.warmup_stats:
                tasks = sched_lib.build_tasks(
                    [{"i": i} for i in range(b)], self.warmup_stats,
                    b0=b, select_tile=lambda m: self.det_cfg.tile,
                    group=max(1, b // 4))
                n_lanes = (sum(self.lanes.values()) if self.lanes else 4)
                sched = sched_lib.lpt_schedule(
                    tasks, n_lanes=max(n_lanes, 1), balance_slack=0.25,
                    mem_cap=self.mem_cap, b_min=1, global_batch=b)
                # accumulate the LPT per-lane predicted loads across
                # request batches — the report's lane_loads field
                if lane_loads is None:
                    lane_loads = [0.0] * len(sched.loads)
                lane_loads = [a + l for a, l in zip(lane_loads,
                                                    sched.loads)]
                off = 0
                for lane in sched.lanes:
                    for task in lane:
                        sl = raw[off: off + task.n_samples]
                        off += task.n_samples
                        if sl.shape[0]:
                            work.append(pad_to_bucket(sl, self.pad_bucket))
            else:
                work.append(pad_to_bucket(raw, self.pad_bucket))

        def feed():
            for tid, (sl, _) in enumerate(work):
                mon.start(tid)
                yield sl

        t0 = time.perf_counter()
        out = self.pipe.run_stream(feed(), lanes=self.lanes)
        wall = time.perf_counter() - t0
        n_img = 0
        for tid, ((_, true_b), res) in enumerate(zip(work,
                                                     out["results"])):
            # slice pad rows back off every per-image field
            for k, v in res.items():
                if getattr(v, "ndim", 0) >= 1:
                    res[k] = v[:true_b]
            n_img += true_b
            mon.complete(tid)
        return ServiceReport(
            images=n_img, wall_s=wall,
            throughput_ips=n_img / wall if wall else 0.0,
            allocation=(self.allocation.streams if self.allocation
                        else None),
            lanes=out.get("lanes"),
            lane_loads=([round(l, 6) for l in lane_loads]
                        if lane_loads else None),
            # speculative re-executions the monitor actually recorded
            # (mark_retried) — not sink-side duplicate completions,
            # which the in-order executor can never produce
            straggler_retries=mon.retry_count)

    # -- data-parallel sharded path ----------------------------------------
    def serve_sharded(self, batches: Iterable) -> ServiceReport:
        """Shard each batch across every local device (1-D data mesh)
        instead of pipelining — the multi-chip scaling axis; combine
        with lanes by running one service per host."""
        from repro.launch.mesh import make_detection_mesh
        mesh = make_detection_mesh()
        n_img = 0
        t0 = time.perf_counter()
        for raw in batches:
            out = self.pipe.run_batch(np.asarray(raw), mesh=mesh)
            n_img += out["ok"].shape[0]
        wall = time.perf_counter() - t0
        return ServiceReport(
            images=n_img, wall_s=wall,
            throughput_ips=n_img / wall if wall else 0.0,
            allocation=None, lanes=None, lane_loads=None)


def enable_compilation_cache(path: str, *, min_entry_bytes: int = 0,
                             min_compile_secs: float = 0.0) -> bool:
    """Point jax's persistent compilation cache at ``path`` so a service
    restart reuses every jitted detection graph (ingest/decode/RS and
    the fused fast path) instead of recompiling — the jit warm-up is the
    dominant cold-start cost for a serving replica.  Returns False when
    this jax build has no persistent cache (knob is then a no-op)."""
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          min_entry_bytes)
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          min_compile_secs)
        return True
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--img", type=int, default=128)
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--mode", default="qrmark")
    ap.add_argument("--rs-mode", default="device",
                    choices=("device", "cpu_pool", "cpu_sync"))
    ap.add_argument("--lanes", type=int, default=0,
                    help="0 = adaptive (Algorithm 1); n = fixed n "
                         "decode/RS lanes")
    ap.add_argument("--ragged", action="store_true",
                    help="send odd-size batches to exercise padding")
    ap.add_argument("--sharded", action="store_true",
                    help="data-parallel run_batch over all local devices")
    ap.add_argument("--staged-ingest", action="store_true",
                    help="disable tile-first ingest (full-image "
                         "preprocess + tile select in decode)")
    ap.add_argument("--decode-dtype", default="fp32",
                    choices=("fp32", "bf16"),
                    help="fused-decode precision policy: fp32 = "
                         "bit-exact vs the unfused extractor, bf16 = "
                         "MXU compute with fp32 accumulation")
    ap.add_argument("--unfused-decode", action="store_true",
                    help="disable the fused Pallas extractor kernel "
                         "(decode runs the unfused XLA graph; warmup "
                         "then profiles and allocates lanes for that)")
    ap.add_argument("--compilation-cache", default="",
                    help="directory for jax's persistent compilation "
                         "cache (reused across service restarts)")
    args = ap.parse_args()

    if args.compilation_cache:
        on = enable_compilation_cache(args.compilation_cache)
        print(f"compilation cache: "
              f"{args.compilation_cache if on else 'unsupported'}")

    from repro.core.extractor import init_extractor
    from repro.core.rs.codec import DEFAULT_CODE
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits)
    cfg = DetectionConfig(tile=args.tile, img_size=args.img,
                          resize_src=args.img + args.img // 8,
                          mode=args.mode, rs_mode=args.rs_mode,
                          tile_first=not args.staged_ingest,
                          fused_decode=not args.unfused_decode,
                          decode_dtype=args.decode_dtype)
    svc = DetectionService(cfg, params, lanes=args.lanes)
    sample = np.stack([data_lib.synth_image(i, args.img + 32)
                       for i in range(args.batch)])
    alloc = svc.warmup(sample)
    print(f"allocation: streams={alloc.streams} J*={alloc.bottleneck_s:.4f} "
          f"lanes={svc.lanes}")
    rng = np.random.default_rng(0)
    sizes = [args.batch if not args.ragged else
             int(rng.integers(1, args.batch + 1))
             for _ in range(args.batches)]
    batches = [np.stack([data_lib.synth_image(1000 + k * args.batch + i,
                                              args.img + 32)
                         for i in range(n)])
               for k, n in enumerate(sizes)]
    rep = svc.serve_sharded(batches) if args.sharded else svc.serve(batches)
    print(json.dumps(dataclasses.asdict(rep), indent=1))


if __name__ == "__main__":
    main()
