"""ML-based tile-size predictor (QRMark Appendix B.2).

The paper uses EfficientNet features + an XGBoost regressor to estimate,
in one forward pass, which tile size an image was watermarked with.  In
this offline container there is no pretrained EfficientNet, so the
feature extractor is adapted to the actual physics of tile watermarks:
embedding the same pattern bank in every l x l grid cell makes the
high-passed image PERIODIC with pitch l, so shifted autocorrelations at
the candidate pitches (+ spectral band energies) are near-sufficient
statistics.  The regressor is gradient-boosted depth-1 trees (stumps)
written from scratch — the same model class as XGBoost.  Both changes
are recorded in DESIGN.md §Adaptations.

Training-data collection and model fitting run offline (no runtime
profiling), matching the paper's deployment.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.extractor import highpass

CANDIDATE_TILES = (16, 32, 48, 64, 80)


# ---------------------------------------------------------------------------
# features
# ---------------------------------------------------------------------------


def tile_features(images) -> np.ndarray:
    """images (b, H, W, 3) float in [-1,1] -> (b, F) features.

    F = shifted autocorrelation of the high-passed image at each
    candidate pitch (both axes) + coarse FFT band energies."""
    x = highpass(jnp.asarray(images, jnp.float32))
    x = x - x.mean(axis=(1, 2, 3), keepdims=True)
    b, H, W, _ = x.shape
    denom = jnp.mean(jnp.square(x), axis=(1, 2, 3)) + 1e-8
    feats = []
    for l in CANDIDATE_TILES:
        if l < H:
            ac_y = jnp.mean(x[:, l:] * x[:, :-l], axis=(1, 2, 3)) / denom
        else:
            ac_y = jnp.zeros((b,))
        if l < W:
            ac_x = jnp.mean(x[:, :, l:] * x[:, :, :-l],
                            axis=(1, 2, 3)) / denom
        else:
            ac_x = jnp.zeros((b,))
        feats += [ac_y, ac_x]
    # coarse spectral bands of the mean channel
    g = x.mean(-1)
    F = jnp.abs(jnp.fft.rfft2(g))
    low = jnp.mean(F[:, : H // 8, : W // 8], axis=(1, 2))
    mid = jnp.mean(F[:, H // 8: H // 4, : W // 4], axis=(1, 2))
    high = jnp.mean(F[:, H // 4:, :], axis=(1, 2))
    tot = low + mid + high + 1e-8
    feats += [low / tot, mid / tot, high / tot]
    return np.asarray(jnp.stack(feats, axis=1))


# ---------------------------------------------------------------------------
# gradient-boosted stumps (from-scratch XGBoost stand-in)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Stump:
    feature: int
    threshold: float
    left: float
    right: float

    def predict(self, X):
        return np.where(X[:, self.feature] <= self.threshold, self.left,
                        self.right)


@dataclasses.dataclass
class BoostedStumps:
    base: float
    stumps: List[Stump]
    lr: float

    def predict(self, X) -> np.ndarray:
        out = np.full(X.shape[0], self.base)
        for s in self.stumps:
            out += self.lr * s.predict(X)
        return out


def fit_boosted_stumps(X, y, *, n_rounds=120, lr=0.15,
                       n_thresholds=16) -> BoostedStumps:
    """L2 gradient boosting with depth-1 trees."""
    X = np.asarray(X, np.float64)
    y = np.asarray(y, np.float64)
    base = float(y.mean())
    pred = np.full_like(y, base)
    stumps: List[Stump] = []
    for _ in range(n_rounds):
        resid = y - pred
        best = None
        for f in range(X.shape[1]):
            xs = X[:, f]
            qs = np.quantile(xs, np.linspace(0.05, 0.95, n_thresholds))
            for t in qs:
                m = xs <= t
                if m.sum() == 0 or m.sum() == len(xs):
                    continue
                lmean = resid[m].mean()
                rmean = resid[~m].mean()
                sse = (np.square(resid[m] - lmean).sum()
                       + np.square(resid[~m] - rmean).sum())
                if best is None or sse < best[0]:
                    best = (sse, Stump(f, float(t), float(lmean),
                                       float(rmean)))
        if best is None:
            break
        stumps.append(best[1])
        pred += lr * best[1].predict(X)
    return BoostedStumps(base, stumps, lr)


# ---------------------------------------------------------------------------
# end-to-end predictor
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TileSizePredictor:
    model: BoostedStumps
    candidates: Sequence[int] = CANDIDATE_TILES

    def predict(self, images) -> np.ndarray:
        raw = self.model.predict(tile_features(images))
        cands = np.asarray(self.candidates, np.float64)
        return cands[np.argmin(np.abs(raw[:, None] - cands[None, :]),
                               axis=1)].astype(int)


def build_training_set(encoder_params_by_tile: dict, *, n_per_tile=64,
                       img_size=128, seed=0):
    """Watermark synthetic images at each tile size with the trained
    encoders; returns (features, labels)."""
    from repro.core import tiling
    from repro.core.extractor import encoder_forward
    from repro.data.pipeline import synth_image

    rng = np.random.default_rng(seed)
    Xs, ys = [], []
    for tile, (enc_params, code) in encoder_params_by_tile.items():
        gy = img_size // tile
        size = gy * tile
        imgs = np.stack([synth_image(seed * 100000 + tile * 1000 + i, size)
                         for i in range(n_per_tile)])
        x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0
        tiles_ = tiling.grid_partition(x, tile)
        b, g = tiles_.shape[:2]
        msgs = jnp.asarray(rng.integers(0, 2,
                                        (b, code.codeword_bits)))
        msgs = jnp.repeat(msgs, g, axis=0)
        xw_flat, _ = encoder_forward(enc_params,
                                     tiles_.reshape(-1, tile, tile, 3),
                                     msgs)
        xw = xw_flat.reshape(b, gy, gy, tile, tile, 3).transpose(
            0, 1, 3, 2, 4, 5).reshape(b, size, size, 3)
        if size != img_size:
            xw = jax.image.resize(xw, (b, img_size, img_size, 3),
                                  "bilinear")
        Xs.append(tile_features(xw))
        ys.append(np.full(b, tile, np.float64))
    return np.concatenate(Xs), np.concatenate(ys)


def train_predictor(encoder_params_by_tile: dict, **kw) -> TileSizePredictor:
    X, y = build_training_set(encoder_params_by_tile, **kw)
    return TileSizePredictor(fit_boosted_stumps(X, y))
