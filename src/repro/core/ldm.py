"""Tile-based LDM decoder fine-tuning (QRMark §4.2, Stable-Signature
recipe at container scale).

A small conv autoencoder stands in for the LDM VAE (f=4 downsampling,
c-channel latents).  ``finetune_decoder`` fine-tunes a copy D_m of the
decoder so that every reconstructed image carries the RS-encoded
signature m_s, recoverable by the FROZEN tile extractor H_D from a
randomly sampled grid tile — exactly the paper's pipeline:

    z = E(x);  x' = D_m(z);  tile -> H_D -> BCE(m', m_s)
    + lambda_i * perceptual(x', D(z))      [frozen original decoder]

The Watson-VGG perceptual loss is replaced by an L2 in the frozen
extractor's early conv feature space (no pretrained VGG exists in this
offline container) — recorded as an adaptation in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, tiling
from repro.core.extractor import conv2d, conv_init, extractor_forward, \
    _block
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.data.pipeline import synth_image
from repro.train import optimizer as opt_lib


# ---------------------------------------------------------------------------
# tiny VAE-style autoencoder (f=4)
# ---------------------------------------------------------------------------


def init_autoencoder(key, *, ch: int = 32, latent: int = 8):
    ks = jax.random.split(key, 8)
    return {
        "enc": {
            "c1": {"w": conv_init(ks[0], 3, 3, 3, ch), "b": jnp.zeros((ch,))},
            "c2": {"w": conv_init(ks[1], 3, 3, ch, ch),
                   "b": jnp.zeros((ch,))},
            "to_z": {"w": conv_init(ks[2], 1, 1, ch, latent),
                     "b": jnp.zeros((latent,))},
        },
        "dec": init_decoder(ks[3], ch=ch, latent=latent),
    }


def init_decoder(key, *, ch: int = 32, latent: int = 8):
    ks = jax.random.split(key, 4)
    return {
        "from_z": {"w": conv_init(ks[0], 3, 3, latent, ch),
                   "b": jnp.zeros((ch,))},
        "c1": {"w": conv_init(ks[1], 3, 3, ch, ch), "b": jnp.zeros((ch,))},
        "c2": {"w": conv_init(ks[2], 3, 3, ch, ch), "b": jnp.zeros((ch,))},
        "out": {"w": conv_init(ks[3], 3, 3, ch, 3), "b": jnp.zeros((3,))},
    }


def _down2(x):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID") / 4.0


def _up2(x):
    b, h, w, c = x.shape
    return jax.image.resize(x, (b, 2 * h, 2 * w, c), "nearest")


def encode(params, x):
    e = params["enc"]
    h = _down2(_block(e["c1"], x))
    h = _down2(_block(e["c2"], h))
    return conv2d(h, e["to_z"]["w"]) + e["to_z"]["b"]


def decode(dec, z):
    h = _block(dec["from_z"], z)
    h = _block(dec["c1"], _up2(h))
    h = _block(dec["c2"], _up2(h))
    return jnp.tanh(conv2d(h, dec["out"]["w"]) + dec["out"]["b"])


# ---------------------------------------------------------------------------
# stage 0: pretrain the autoencoder (reconstruction)
# ---------------------------------------------------------------------------


def pretrain_autoencoder(key, *, img_size=64, steps=150, batch=16,
                         verbose=False):
    params = init_autoencoder(key)
    opt_cfg = opt_lib.AdamWConfig(lr=2e-3, warmup_steps=20,
                                  total_steps=steps, weight_decay=0.0,
                                  clip_norm=10.0)
    opt = opt_lib.init_opt_state(params)

    @jax.jit
    def step(params, opt, x):
        def loss_fn(p):
            return jnp.mean(jnp.square(decode(p["dec"], encode(p, x)) - x))
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = opt_lib.adamw_update(opt_cfg, params, g, opt)
        return params, opt, loss

    for i in range(steps):
        imgs = np.stack([synth_image(i * batch + j, img_size)
                         for j in range(batch)])
        x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0
        params, opt, loss = step(params, opt, x)
        if verbose and i % 50 == 0:
            print(f"[ae] step {i} recon={float(loss):.4f}", flush=True)
    return params


# ---------------------------------------------------------------------------
# stage 1: fine-tune D_m against the frozen extractor (paper §4.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FinetuneResult:
    decoder: dict
    history: list
    signature: np.ndarray  # the RS-encoded codeword bits m_s


def extractor_features(hd_params, x, n_blocks=2):
    h = x
    for blk in hd_params["blocks"][:n_blocks]:
        h = _block(blk, h)
    return h


def finetune_decoder(ae_params, hd_params, *, code=DEFAULT_CODE,
                     message_bits: Optional[np.ndarray] = None,
                     tile: int = 16, img_size: int = 64, steps: int = 100,
                     batch: int = 4, lam_i: float = 2.0, lr: float = 1e-4,
                     seed: int = 0, verbose=False) -> FinetuneResult:
    """AdamW for ``steps`` iterations (paper: 100 iters, batch 4,
    warmup 20 to 1e-4 then decay)."""
    rng = np.random.default_rng(seed)
    if message_bits is None:
        message_bits = rng.integers(0, 2, code.message_bits)
    m_s = jnp.asarray(rs_encode(code, message_bits))  # codeword bits

    dec_m = jax.tree.map(jnp.copy, ae_params["dec"])  # D_m init = D
    frozen_dec = ae_params["dec"]
    opt_cfg = opt_lib.AdamWConfig(lr=lr, warmup_steps=20, total_steps=steps,
                                  weight_decay=0.0, clip_norm=10.0,
                                  min_lr_frac=0.01)
    opt = opt_lib.init_opt_state(dec_m)

    @jax.jit
    def step(dec_m, opt, x, key):
        z = encode(ae_params, x)  # frozen encoder

        def loss_fn(dm):
            x_w = decode(dm, z)
            tiles_, _ = tiling.select_tiles("random_grid", key, x_w, tile)
            logits = extractor_forward(hd_params, tiles_)
            msg = jnp.broadcast_to(m_s, (x.shape[0], m_s.shape[0]))
            l_m = losses.message_loss(logits, msg)
            # perceptual proxy: frozen-extractor feature L2 vs D(z)
            x_o = decode(frozen_dec, z)
            l_i = jnp.mean(jnp.square(
                extractor_features(hd_params, x_w)
                - extractor_features(hd_params, x_o)))
            l_i = l_i + jnp.mean(jnp.square(x_w - x_o))
            acc = losses.bit_accuracy(logits, msg)
            return l_m + lam_i * l_i, (l_m, l_i, acc)

        (loss, (l_m, l_i, acc)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(dec_m)
        dec_m, opt, _ = opt_lib.adamw_update(opt_cfg, dec_m, g, opt)
        return dec_m, opt, loss, l_m, l_i, acc

    key = jax.random.key(seed)
    hist = []
    for i in range(steps):
        imgs = np.stack([synth_image(5_000_000 + i * batch + j, img_size)
                         for j in range(batch)])
        x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0
        key, k = jax.random.split(key)
        dec_m, opt, loss, l_m, l_i, acc = step(dec_m, opt, x, k)
        if i % 20 == 0 or i == steps - 1:
            hist.append({"step": i, "loss": float(loss),
                         "L_m": float(l_m), "L_i": float(l_i),
                         "bit_acc": float(acc)})
            if verbose:
                print(f"[ft] step {i:3d} loss={float(loss):.4f} "
                      f"L_m={float(l_m):.4f} acc={float(acc):.3f}",
                      flush=True)
    return FinetuneResult(dec_m, hist, np.asarray(m_s))
