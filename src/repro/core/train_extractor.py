"""QRMark offline stage (§4.1): pre-train the tile-based watermark
encoder H_E + extractor H_D with the RS-aware loss.

Faithful to the paper's recipe at container scale:
  * partition each training image into an l x l grid, sample one cell
    (random_grid), embed a (RS-encoded) message as a residual, apply a
    random transform T from the attack set, extract, optimise
    L = L_m + lambda * L_RS (+ a small imperceptibility term on delta).
  * AdamW, warmup->cosine; batch and channel counts sized for CPU.

The resulting params feed the detection pipeline and every accuracy
benchmark (Tables 2-5).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, tiling, transforms
from repro.core.extractor import (encoder_forward, extractor_forward,
                                  init_encoder, init_extractor)
from repro.core.rs.codec import DEFAULT_CODE, RSCode
from repro.data.pipeline import synth_image
from repro.train import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class ExtractorTrainConfig:
    code: RSCode = DEFAULT_CODE
    tile: int = 32
    img_size: int = 128
    alpha: float = 1.0
    lam_rs: float = 1.0
    lam_img: float = 0.0  # PSNR pinned by power-normalised embedding
    channels: int = 24
    depth: int = 4
    enc_channels: int = 24
    enc_depth: int = 3
    batch: int = 32
    steps: int = 400
    lr: float = 3e-3
    seed: int = 0
    strategy: str = "random_grid"
    # training transform set T (differentiable surrogates)
    train_attacks: Tuple[str, ...] = ("none", "none", "blur", "jpeg_50",
                                      "brightness_2", "contrast_2",
                                      "resize_0.5")
    # curriculum: first this fraction of steps trains clean (attack 0 =
    # 'none'), then the full transform set T kicks in
    curriculum_frac: float = 0.5


TRAIN_ATTACK_FNS = transforms.ATTACKS


def make_train_step(cfg: ExtractorTrainConfig):
    n_bits = cfg.code.codeword_bits
    opt_cfg = opt_lib.AdamWConfig(lr=cfg.lr, warmup_steps=40,
                                  total_steps=cfg.steps, weight_decay=0.01,
                                  clip_norm=10.0, b2=0.99)

    def loss_fn(params, tiles, messages, attack_idx, key):
        xw, delta = encoder_forward(params["enc"], tiles, messages,
                                    alpha=cfg.alpha)
        # apply each attack to the whole batch, select per-sample
        atk_outs = [TRAIN_ATTACK_FNS[a](xw) for a in cfg.train_attacks]
        stack = jnp.stack(atk_outs)  # (A, b, l, l, 3)
        xw_t = jnp.take_along_axis(
            stack, attack_idx[None, :, None, None, None], axis=0)[0]
        logits = extractor_forward(params["dec"], xw_t)
        total, parts = losses.qrmark_loss(logits, messages, code=cfg.code,
                                          lam=cfg.lam_rs)
        l_img = jnp.mean(jnp.square(delta))
        parts["L_img"] = l_img
        parts["bit_acc"] = losses.bit_accuracy(logits, messages)
        return total + cfg.lam_img * l_img, parts

    @jax.jit
    def step(params, opt_state, tiles, messages, attack_idx, key):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, tiles, messages, attack_idx, key)
        params, opt_state, m = opt_lib.adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        parts["loss"] = loss
        parts["grad_norm"] = m["grad_norm"]
        return params, opt_state, parts

    return step


def batch_tiles(cfg: ExtractorTrainConfig, step_idx: int, key):
    """Host-side batch prep: images -> normalized tiles + messages."""
    imgs = np.stack([synth_image(step_idx * cfg.batch + i, cfg.img_size,
                                 cfg.seed) for i in range(cfg.batch)])
    x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0  # [-1, 1]
    tiles_, _ = tiling.select_tiles(cfg.strategy, key, x, cfg.tile)
    return tiles_


def train(cfg: ExtractorTrainConfig, *, log_every: int = 50,
          init_params: Optional[dict] = None, verbose=True) -> dict:
    key = jax.random.key(cfg.seed)
    n_bits = cfg.code.codeword_bits
    k1, k2, key = jax.random.split(key, 3)
    if init_params is None:
        enc = init_encoder(k1, n_bits=n_bits, channels=cfg.enc_channels,
                           depth=cfg.enc_depth, tile=cfg.tile)
        dec = init_extractor(k2, n_bits=n_bits, channels=cfg.channels,
                             depth=cfg.depth, tile=cfg.tile,
                             patterns=enc["patterns"])  # tied warm-start
        params = {"enc": enc, "dec": dec}
    else:
        params = init_params
    opt_state = opt_lib.init_opt_state(params)
    step = make_train_step(cfg)
    history = []
    t0 = time.time()
    for i in range(cfg.steps):
        key, kt, km, ka, ks = jax.random.split(key, 5)
        tiles_ = batch_tiles(cfg, i, kt)
        messages = jax.random.randint(km, (cfg.batch, n_bits), 0, 2)
        if i < cfg.curriculum_frac * cfg.steps:
            attack_idx = jnp.zeros((cfg.batch,), jnp.int32)  # clean phase
        else:
            attack_idx = jax.random.randint(ka, (cfg.batch,), 0,
                                            len(cfg.train_attacks))
        params, opt_state, parts = step(params, opt_state, tiles_, messages,
                                        attack_idx, ks)
        if i % log_every == 0 or i == cfg.steps - 1:
            rec = {k: float(v) for k, v in parts.items()}
            rec["step"] = i
            rec["wall_s"] = time.time() - t0
            history.append(rec)
            if verbose:
                print(f"step {i:4d} loss={rec['loss']:.4f} "
                      f"bit_acc={rec['bit_acc']:.3f} "
                      f"L_RS={rec['L_RS']:.4f} ({rec['wall_s']:.0f}s)",
                      flush=True)
    return {"params": params, "history": history, "config": cfg}


# ---------------------------------------------------------------------------
# evaluation: embed -> (attack) -> extract -> RS decode
# ---------------------------------------------------------------------------


def evaluate(params, cfg: ExtractorTrainConfig, *, n_images: int = 128,
             attacks: Tuple[str, ...] = ("none",), tile: Optional[int] = None,
             strategy: Optional[str] = None, use_rs: bool = True,
             message_bits: Optional[np.ndarray] = None,
             seed: int = 1234) -> Dict[str, Dict[str, float]]:
    """Returns {attack: {bit_acc, word_acc, rs_word_acc, psnr}}."""
    from repro.core.rs import jax_rs

    from repro.core.rs.codec import rs_encode

    tile = tile or cfg.tile
    strategy = strategy or cfg.strategy
    code = cfg.code
    n_bits = code.codeword_bits
    key = jax.random.key(seed)
    if message_bits is None:
        rng = np.random.default_rng(seed)
        message_bits = rng.integers(0, 2, code.message_bits)
    # the embedded payload is the RS-encoded signature m_s (paper §4.2)
    codeword = jnp.asarray(rs_encode(code, np.asarray(message_bits)))
    msg = jnp.broadcast_to(codeword, (n_images, n_bits))
    decoder = jax_rs.make_batch_decoder(code)

    imgs = np.stack([synth_image(10_000_000 + i, cfg.img_size, seed)
                     for i in range(n_images)])
    x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0

    # embed into EVERY grid tile so any sampled tile carries the watermark
    gy = cfg.img_size // tile
    all_tiles = tiling.grid_partition(x, tile)  # (b, g, l, l, 3)
    b, g = all_tiles.shape[:2]
    flat = all_tiles.reshape(b * g, tile, tile, 3)
    msg_rep = jnp.repeat(msg, g, axis=0)
    xw_flat, _ = encoder_forward(params["enc"], flat, msg_rep,
                                 alpha=cfg.alpha)
    xw_tiles = xw_flat.reshape(b, gy, gy, tile, tile, 3)
    xw = xw_tiles.transpose(0, 1, 3, 2, 4, 5).reshape(
        b, gy * tile, gy * tile, 3)
    # PSNR over the watermarked region
    mse = jnp.mean(jnp.square(
        xw - x[:, : gy * tile, : gy * tile])) + 1e-12
    psnr = float(10 * jnp.log10(4.0 / mse))  # range [-1,1] -> peak 2

    out = {}
    for attack in attacks:
        xa = transforms.ATTACKS[attack](xw)
        key, kt = jax.random.split(key)
        tiles_, _ = tiling.select_tiles(strategy, kt, xa, tile)
        logits = extractor_forward(params["dec"], tiles_)
        bits = (logits > 0).astype(jnp.int32)
        bit_acc = float(losses.bit_accuracy(logits, msg))
        word_acc = float(losses.word_accuracy(bits, msg))
        rec = {"bit_acc": bit_acc, "word_acc_raw": word_acc, "psnr": psnr}
        if use_rs:
            dec = decoder(bits)
            ok = np.asarray(dec["ok"])
            m_out = np.asarray(dec["message_bits"])
            gt = np.asarray(message_bits)
            match = ok & np.all(m_out == gt[None, :], axis=1)
            rec["rs_word_acc"] = float(match.mean())
            rec["rs_bit_acc"] = float(
                (m_out == gt[None, :]).mean())
        out[attack] = rec
    return out
