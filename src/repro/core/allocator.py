"""Algorithm 1 — Adaptive Streams Allocation (QRMark §5.2), adapted to TPU
*lanes*.

On GPU the paper assigns CUDA streams to pipeline stages; the TPU analogue
is a *lane*: an independent executor slot (a device group slice of the
detection mesh's data axis, or an async dispatch slot on a single chip)
through which a stage's mini-batches flow.  The algorithm is unchanged:

  1. warm-up profiling of per-stage time t[k] and per-sample memory u[k];
  2. greedy hill-climb: add one lane to the stage that most reduces the
     bottleneck latency J* = max_k TIME(k, s[k], m[k]), subject to the
     memory cap and the global lane budget; stall-counter termination;
  3. mini-batch leveling for stages far faster than the bottleneck.

TIME(k, s, m) models a stage whose step time scales with its share of the
batch and inversely with lanes, plus a per-launch overhead — the same
first-order model the paper's profile-driven search uses (and the reason
a (1,1,16) allocation helps at B=256 but hurts at B=16).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class StageProfile:
    name: str
    t_per_sample: float       # seconds per sample at batch b0 (warm-up)
    u_per_sample: float       # bytes per in-flight sample
    launch_overhead: float    # per-minibatch dispatch cost (seconds)


@dataclasses.dataclass
class Allocation:
    streams: List[int]          # s[1..K] lanes per stage
    minibatch: List[int]        # m[1..K] minibatch size per stage
    bottleneck_s: float         # J*
    history: List[Tuple[List[int], float]]  # search trace


def stage_time(p: StageProfile, s: int, m: int, B: int) -> float:
    """Predicted per-global-batch time for stage p with s lanes of
    minibatch m.

    Each *wave* dispatches one minibatch to each of the s lanes: the host
    serialises the s dispatches (s * launch_overhead) while the lanes
    compute in parallel (m * t).  waves = ceil(B / (s*m)).  This is the
    first-order model behind the paper's observations: at B=256 extra
    streams shrink the wave count (1.43x), at B=16 they only add launch
    overhead (0.86x)."""
    waves = -(-B // max(s * m, 1))
    return waves * (m * p.t_per_sample + s * p.launch_overhead)


def mem_ok(profiles: Sequence[StageProfile], s: List[int], m: List[int],
           cap: float) -> bool:
    return sum(si * mi * p.u_per_sample
               for p, si, mi in zip(profiles, s, m)) <= cap


def adaptive_allocation(profiles: Sequence[StageProfile], *, global_batch: int,
                        stream_budget: int = 32, mem_cap: float = 16e9,
                        eps: float = 1e-4, stall_cap: int = 3,
                        max_iters: int = 64) -> Allocation:
    """Algorithm 1, faithful to the paper's pseudocode."""
    K = len(profiles)
    # Step 1: init one lane per stage; largest uniform minibatch in budget
    s = [1] * K
    m_uni = global_batch
    while m_uni > 1 and not mem_ok(profiles, s, [m_uni] * K, mem_cap):
        m_uni //= 2
    m = [max(m_uni, 1)] * K

    def J(s_, m_):
        return max(stage_time(p, si, mi, global_batch)
                   for p, si, mi in zip(profiles, s_, m_))

    j_star = J(s, m)
    stall = 0
    history = [(list(s), j_star)]

    def fit_m(s_):
        mu = global_batch
        while mu > 1 and not mem_ok(profiles, s_, [mu] * K, mem_cap):
            mu //= 2
        return [max(mu, 1)] * K

    # Step 2: adaptive search.  (Each candidate re-fits the largest
    # feasible uniform minibatch — the paper fits m once at init; the
    # refit keeps the memory constraint coherent as streams grow.)
    iters = 0
    while stall < stall_cap and iters < max_iters:
        iters += 1
        gain, best = 0.0, (s, m)
        for k in range(K):
            if sum(s) + 1 > stream_budget:
                continue
            s2 = list(s)
            s2[k] += 1
            m2 = fit_m(s2)
            if not mem_ok(profiles, s2, m2, mem_cap):
                continue
            j2 = J(s2, m2)
            delta = j_star - j2
            if delta > gain:
                gain, best = delta, (s2, m2)
        if gain > eps:
            s, m = best
            j_star = J(s, m)
            stall = 0
            history.append((list(s), j_star))
        else:
            stall += 1

    # Step 3: mini-batch leveling
    u_s = sum(s)
    m_unit = max(1, global_batch // max(u_s, 1))
    for k in range(K):
        tk = stage_time(profiles[k], s[k], m[k], global_batch)
        if tk < 0.5 * j_star:
            m2 = list(m)
            m2[k] = min(m_unit, 2 * m[k])
            if mem_ok(profiles, s, m2, mem_cap):
                m = m2
    return Allocation(s, m, J(s, m), history)


def assign(profiles: Sequence[StageProfile], *, global_batch: int,
           lane_budget: int = 8, mem_cap: float = 16e9
           ) -> Dict[str, int]:
    """{stage name: lane count} for the lane executor — Algorithm 1's
    stream vector keyed by stage so :class:`repro.core.lanes.Stage`
    assignments can be looked up by name."""
    alloc = adaptive_allocation(profiles, global_batch=global_batch,
                                stream_budget=lane_budget, mem_cap=mem_cap)
    return {p.name: max(1, int(s))
            for p, s in zip(profiles, alloc.streams)}


# ---------------------------------------------------------------------------
# warm-up profiling (Step 1 of the paper's algorithm)
# ---------------------------------------------------------------------------


def profile_stage(fn: Callable, sample_batch, *, iters: int = 3,
                  bytes_per_sample: Optional[float] = None,
                  name: str = "stage") -> StageProfile:
    """Measure t[k]/u[k] by running ``fn`` on a warm-up batch."""
    import jax
    import numpy as np

    b = jax.tree.leaves(sample_batch)[0].shape[0]
    fn(sample_batch)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(sample_batch)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    if bytes_per_sample is None:
        bytes_per_sample = sum(
            np.prod(l.shape) * l.dtype.itemsize
            for l in jax.tree.leaves(sample_batch)) / b
    # crude launch overhead estimate: run at batch 1
    one = jax.tree.map(lambda x: x[:1], sample_batch)
    fn(one)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(one)
    jax.block_until_ready(out)
    dt1 = (time.perf_counter() - t0) / iters
    per_sample = max((dt - dt1) / max(b - 1, 1), 1e-9)
    overhead = max(dt1 - per_sample, 0.0)
    return StageProfile(name, per_sample, float(bytes_per_sample), overhead)
