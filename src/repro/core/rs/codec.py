"""Systematic evaluation-based Reed-Solomon codec (paper Appendix A).

Encoding: Lagrange-interpolate P(x) (deg < k) through (X_i, M_i) for the
first k evaluation points, then evaluate at all n points — systematic:
C_i = M_i for i < k.  Decoding: Berlekamp-Welch via Gaussian elimination
over GF(2^m) (O(n^3), "smaller in practice"), returning the corrected
message bits, full codeword bits, and the number of symbols corrected.

This is the scalar numpy REFERENCE (and the paper-faithful CPU path); the
batched on-device decoder lives in jax_rs.py and is tested against this.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.rs.gf import GF, bits_to_symbols, symbols_to_bits


@dataclasses.dataclass(frozen=True)
class RSCode:
    m: int          # bits per symbol
    n: int          # codeword symbols (<= 2^m - 1)
    k: int          # message symbols

    def __post_init__(self):
        assert self.n <= (1 << self.m) - 1, "RS length bound n_max = 2^m-1"
        assert 0 < self.k <= self.n

    @property
    def t(self) -> int:
        return (self.n - self.k) // 2

    @property
    def message_bits(self) -> int:
        return self.k * self.m

    @property
    def codeword_bits(self) -> int:
        return self.n * self.m

    @property
    def eval_points(self) -> np.ndarray:
        # alpha^0 .. alpha^{n-1}: pairwise distinct, never 0
        exp, _ = __import__("repro.core.rs.gf", fromlist=["tables"]).tables(
            self.m)
        return exp[: self.n].copy()


# default code from the paper: GF(16), n=15, k=12 -> 48-bit payload, t=1
DEFAULT_CODE = RSCode(m=4, n=15, k=12)


def _lagrange_coeffs(gf: GF, xs: np.ndarray, ys: np.ndarray) -> np.ndarray:
    """Coefficients of the unique P (deg < len(xs)) with P(xs)=ys. O(k^2)."""
    kk = len(xs)
    poly = np.zeros(kk, np.int32)
    for i in range(kk):
        if ys[i] == 0:
            continue
        # basis ell_i(x) = prod_{j != i} (x - X_j) / (X_i - X_j)
        basis = np.array([1], np.int32)
        denom = 1
        for j in range(kk):
            if j == i:
                continue
            basis = gf.poly_mul(basis, [xs[j], 1])  # (x + X_j) in char 2
            denom = int(gf.mul(denom, gf.add(xs[i], xs[j])))
        scale = gf.mul(ys[i], gf.inv(denom))
        contrib = gf.mul(np.int32(scale), basis)
        poly[: len(contrib)] ^= contrib
    return poly


def rs_encode(code: RSCode, message_bits) -> np.ndarray:
    """message_bits (k*m,) -> codeword bits (n*m,).  Systematic."""
    gf = GF(code.m)
    msg = bits_to_symbols(message_bits, code.m)
    assert len(msg) == code.k
    xs = code.eval_points
    poly = _lagrange_coeffs(gf, xs[: code.k], msg)
    cw = gf.poly_eval(poly, xs)
    cw[: code.k] = msg  # exact systematic property
    return symbols_to_bits(cw, code.m)


@dataclasses.dataclass
class DecodeResult:
    message_bits: np.ndarray      # corrected k*m bits
    codeword_bits: np.ndarray     # corrected n*m bits
    n_corrected: int              # symbol errors fixed (-1 if failed)
    ok: bool


def rs_decode(code: RSCode, received_bits) -> DecodeResult:
    """Berlekamp-Welch decode of an n*m bit string."""
    gf = GF(code.m)
    R = bits_to_symbols(received_bits, code.m)
    n, k, t = code.n, code.k, code.t
    xs = code.eval_points

    # Fast path: received word may already be a codeword
    poly = _lagrange_coeffs(gf, xs[:k], R[:k])
    if np.array_equal(gf.poly_eval(poly, xs), R):
        return DecodeResult(symbols_to_bits(R[:k], code.m),
                            np.asarray(received_bits), 0, True)

    # B-W: N(X_i) = R_i Q(X_i); unknowns [q_0..q_t, n_0..n_{t+k-1}]
    nq, nn = t + 1, t + k
    A = np.zeros((n, nq + nn), np.int32)
    for i in range(n):
        xp = 1
        for j in range(nq):
            A[i, j] = gf.mul(R[i], xp)
            xp = int(gf.mul(xp, xs[i]))
        xp = 1
        for j in range(nn):
            A[i, nq + j] = xp  # char 2: -X^j == X^j
            xp = int(gf.mul(xp, xs[i]))

    sol = _gf_nullspace(gf, A)
    if sol is None:
        return DecodeResult(symbols_to_bits(R[:k], code.m),
                            np.asarray(received_bits), -1, False)
    Q, N = sol[:nq], sol[nq:]
    if not Q.any():
        return DecodeResult(symbols_to_bits(R[:k], code.m),
                            np.asarray(received_bits), -1, False)
    P, rem = gf.poly_divmod(N, Q)
    if rem.any():
        return DecodeResult(symbols_to_bits(R[:k], code.m),
                            np.asarray(received_bits), -1, False)
    P = P[:k] if len(P) >= k else np.pad(P, (0, k - len(P)))
    cw = gf.poly_eval(P, xs)
    n_err = int(np.sum(cw != R))
    ok = n_err <= t
    msg = gf.poly_eval(P, xs[:k])
    return DecodeResult(symbols_to_bits(msg, code.m),
                        symbols_to_bits(cw, code.m),
                        n_err if ok else -1, ok)


def _gf_nullspace(gf: GF, A: np.ndarray) -> Optional[np.ndarray]:
    """A non-trivial nullspace vector of A (rows x cols, cols = rows+1)."""
    A = A.copy()
    rows, cols = A.shape
    pivot_col_of_row = [-1] * rows
    r = 0
    for c in range(cols):
        if r >= rows:
            break
        pivots = np.nonzero(A[r:, c])[0]
        if len(pivots) == 0:
            continue
        pr = r + pivots[0]
        A[[r, pr]] = A[[pr, r]]
        A[r] = gf.mul(A[r], gf.inv(A[r, c]))
        for rr in range(rows):
            if rr != r and A[rr, c]:
                A[rr] = gf.add(A[rr], gf.mul(A[rr, c], A[r]))
        pivot_col_of_row[r] = c
        r += 1
    pivot_cols = set(pivot_col_of_row[:r])
    free = [c for c in range(cols) if c not in pivot_cols]
    if not free:
        return None
    f = free[0]
    x = np.zeros(cols, np.int32)
    x[f] = 1
    for rr in range(r):
        x[pivot_col_of_row[rr]] = A[rr, f]  # char 2: -a == a
    return x
