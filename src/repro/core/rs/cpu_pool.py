"""Paper-faithful CPU-side RS correction: input queue + thread pool +
codebook cache (QRMark §5.3).

The decoded raw messages m' are dispatched to idle CPU threads for
correction and the corrected outputs c_s are collected asynchronously, so
device->host transfers and CPU compute never stall the accelerator
pipeline.  A codebook cb maps recurring m' to c_s, with an access counter
per entry (the embedded message set is small and detection accuracy is
high, so raw messages recur constantly).

This is the BASELINE path; the beyond-paper on-device decoder is
jax_rs.make_batch_decoder.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.rs.codec import RSCode, rs_decode


class RSCodebook:
    """m' -> c_s cache with LRU-ish counter eviction (QRMark §5.3)."""

    def __init__(self, capacity: int = 65536):
        self.capacity = capacity
        self._cb: Dict[bytes, Tuple[np.ndarray, bool]] = {}
        self._count: Dict[bytes, int] = {}  # images since last access
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def lookup(self, raw_bits: np.ndarray):
        key = np.packbits(raw_bits.astype(np.uint8)).tobytes()
        with self._lock:
            for k in list(self._count):
                self._count[k] += 1
            if key in self._cb:
                self._count[key] = 0
                self.hits += 1
                return self._cb[key]
            self.misses += 1
            return None

    def insert(self, raw_bits: np.ndarray, corrected: np.ndarray, ok: bool):
        key = np.packbits(raw_bits.astype(np.uint8)).tobytes()
        with self._lock:
            if len(self._cb) >= self.capacity:
                # evict the stalest entry
                stale = max(self._count, key=self._count.get)
                self._cb.pop(stale, None)
                self._count.pop(stale, None)
            self._cb[key] = (corrected, ok)
            self._count[key] = 0

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


@dataclass
class RSWorkItem:
    seq: int
    raw_bits: np.ndarray


class RSCorrectionPool:
    """Thread-pool RS corrector with an input queue (QRMark §5.3).

    submit() is non-blocking; results are collected with drain()/result().
    """

    def __init__(self, code: RSCode, n_threads: int = 32,
                 codebook: Optional[RSCodebook] = None):
        self.code = code
        self.codebook = codebook if codebook is not None else RSCodebook()
        self._in: "queue.Queue[Optional[RSWorkItem]]" = queue.Queue()
        self._results: Dict[int, Tuple[np.ndarray, bool]] = {}
        self._rlock = threading.Lock()
        self._rcond = threading.Condition(self._rlock)
        self._threads: List[threading.Thread] = []
        self._stop = False
        for _ in range(n_threads):
            t = threading.Thread(target=self._worker, daemon=True)
            t.start()
            self._threads.append(t)

    def _worker(self):
        while True:
            item = self._in.get()
            if item is None:
                return
            cached = self.codebook.lookup(item.raw_bits)
            if cached is not None:
                msg, ok = cached
            else:
                res = rs_decode(self.code, item.raw_bits)
                msg, ok = res.message_bits, res.ok
                self.codebook.insert(item.raw_bits, msg, ok)
            with self._rcond:
                self._results[item.seq] = (msg, ok)
                self._rcond.notify_all()

    def submit(self, seq: int, raw_bits: np.ndarray):
        self._in.put(RSWorkItem(seq, np.asarray(raw_bits)))

    def submit_batch(self, raw_bits_batch: np.ndarray, base_seq: int = 0):
        for i, rb in enumerate(raw_bits_batch):
            self.submit(base_seq + i, rb)

    def result(self, seq: int, timeout: float = 30.0):
        with self._rcond:
            while seq not in self._results:
                if not self._rcond.wait(timeout):
                    raise TimeoutError(f"RS result {seq} not ready")
            return self._results.pop(seq)

    def drain(self, seqs, timeout: float = 30.0):
        return [self.result(s, timeout) for s in seqs]

    def close(self):
        for _ in self._threads:
            self._in.put(None)
        for t in self._threads:
            t.join(timeout=5)
