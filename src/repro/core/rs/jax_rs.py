"""Batched, branch-free Reed-Solomon decode in pure JAX — the TPU-native
replacement for the paper's CPU thread-pool RS stage.

The paper keeps RS on the CPU because the classical decoder is branchy
("many interdependent instruction flows").  On TPU we restructure it:

* GF(2^m) arithmetic = XOR + log/exp table gathers (VPU-friendly);
* Berlekamp-Welch's Gaussian elimination runs with *masked pivoting*
  (select instead of swap, multiply-by-mask instead of branch) over the
  fixed-size (n, n+1) system — identical algebra, zero data-dependent
  control flow;
* message recovery avoids polynomial long division (whose loop bounds are
  data-dependent): error locations are the zeros of Q, the k first
  error-free symbols are selected with a stable argsort, and P is
  re-interpolated through them (Lagrange, O(k^2) table ops).

``decode_batch`` is jit/vmap-compatible, so RS correction fuses into the
detection graph — no device->host sync, no thread pool.  The thread-pool
path (cpu_pool.py) is retained as the paper-faithful baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rs import gf as gf_np
from repro.core.rs.codec import RSCode


@functools.lru_cache(maxsize=None)
def _consts(code: RSCode):
    exp, log = gf_np.tables(code.m)
    xs = exp[: code.n].copy()
    return (jnp.asarray(exp, jnp.int32), jnp.asarray(log, jnp.int32),
            jnp.asarray(xs, jnp.int32))


def _mk_ops(exp, log, q):
    def mul(a, b):
        out = exp[(log[a] + log[b])]
        return jnp.where((a == 0) | (b == 0), 0, out)

    def inv(a):  # inv(0) := 0 (always masked by callers)
        return jnp.where(a == 0, 0, exp[(q - 1 - log[a]) % (q - 1)])

    return mul, inv


def bits_to_symbols(bits, m):
    b = bits.reshape(bits.shape[:-1] + (-1, m)).astype(jnp.int32)
    w = (1 << jnp.arange(m - 1, -1, -1)).astype(jnp.int32)
    return (b * w).sum(-1)


def symbols_to_bits(sym, m):
    sh = jnp.arange(m - 1, -1, -1)
    return ((sym[..., None] >> sh) & 1).reshape(sym.shape[:-1] + (-1,))


def _nullspace_masked(A, mul, inv):
    """RREF with masked pivoting; returns a nullspace vector.

    A: (rows, cols) with cols = rows + 1 over GF(2^m).  Branch-free: the
    pivot 'swap' is a select, eliminated rows are masked adds.
    """
    rows, cols = A.shape
    pivot_col = jnp.full((rows,), cols, jnp.int32)  # cols = "no pivot"
    row_idx = jnp.arange(rows)

    def col_step(state, c):
        A, pivot_col, r = state
        colv = A[:, c]
        eligible = (row_idx >= r) & (colv != 0)
        has = eligible.any()
        pr = jnp.argmax(eligible)  # first eligible row
        # swap rows r <-> pr via select
        Ar, Apr = A[r], A[pr]
        A = A.at[r].set(jnp.where(has, Apr, Ar))
        A = A.at[pr].set(jnp.where(has, Ar, Apr))
        # normalise pivot row
        piv = A[r, c]
        A = A.at[r].set(jnp.where(has, mul(A[r], inv(piv)), A[r]))
        # eliminate this column from all other rows
        factors = jnp.where((row_idx != r) & has, A[:, c], 0)
        A = jnp.bitwise_xor(A, mul(factors[:, None],
                                   A[r][None, :]))
        pivot_col = pivot_col.at[r].set(jnp.where(has, c, pivot_col[r]))
        r = jnp.minimum(r + has.astype(jnp.int32), rows)
        return (A, pivot_col, r), None

    (A, pivot_col, _), _ = jax.lax.scan(
        col_step, (A, pivot_col, jnp.int32(0)), jnp.arange(cols))
    # first free column: smallest c not in pivot_col
    is_pivot = jnp.zeros((cols + 1,), bool).at[pivot_col].set(True)[:cols]
    free = jnp.argmin(is_pivot)  # first False
    x = jnp.zeros((cols,), jnp.int32).at[free].set(1)
    # x[pivot_col[r]] = A[r, free]
    vals = A[row_idx, free]
    x = x.at[jnp.where(pivot_col < cols, pivot_col, cols)].set(
        jnp.where(pivot_col < cols, vals, 0), mode="drop")
    return x


def _lagrange_eval(xs_sel, ys_sel, x_eval, mul, inv):
    """Evaluate the interpolant through (xs_sel, ys_sel) at x_eval.

    xs_sel/ys_sel: (k,); x_eval: (p,).  Fully vectorised barycentric-style
    form: P(x) = sum_i y_i * prod_{j!=i} (x ^ X_j) * inv(prod (X_i ^ X_j)).
    """
    k = xs_sel.shape[0]
    eye = jnp.eye(k, dtype=bool)
    # denominators: prod_{j != i} (X_i + X_j)
    diff = jnp.bitwise_xor(xs_sel[:, None], xs_sel[None, :])
    diff = jnp.where(eye, 1, diff)

    def prod_reduce(v, axis):
        def body(c, x):
            return mul(c, x), None
        vm = jnp.moveaxis(v, axis, 0)
        out, _ = jax.lax.scan(body, jnp.ones(vm.shape[1:], jnp.int32), vm)
        return out

    denom = prod_reduce(diff, 1)            # (k,)
    wgt = mul(ys_sel, inv(denom))           # (k,)
    # numerators per eval point: prod_{j != i} (x + X_j)
    xd = jnp.bitwise_xor(x_eval[:, None], xs_sel[None, :])  # (p, k)
    full = prod_reduce(xd, 1)               # (p,) prod over ALL j
    # handle x == X_i: product excluding i needed -> compute explicitly
    excl = jnp.where(eye[None, :, :], 1, xd[:, None, :])    # (p, k, k)
    num = prod_reduce(excl.reshape(-1, k), 1).reshape(-1, k)  # (p, k)
    terms = mul(wgt[None, :], num)
    # XOR-accumulate
    return jax.lax.reduce(terms, jnp.int32(0),
                          jnp.bitwise_xor, dimensions=(1,))


def make_decoder(code: RSCode):
    """Returns decode(bits (..., n*m)) -> dict with corrected bits etc."""
    exp, log, xs = _consts(code)
    q = 1 << code.m
    n, k, t = code.n, code.k, code.t
    mul, inv = _mk_ops(exp, log, q)
    nq, nn = t + 1, t + k

    # Vandermonde powers X_i^j
    powsQ = np.ones((n, nq), np.int64)
    powsN = np.ones((n, nn), np.int64)
    g = gf_np.GF(code.m)
    for i in range(n):
        for j in range(1, nq):
            powsQ[i, j] = g.mul(powsQ[i, j - 1], int(xs[i]))
        for j in range(1, nn):
            powsN[i, j] = g.mul(powsN[i, j - 1], int(xs[i]))
    powsQ = jnp.asarray(powsQ, jnp.int32)
    powsN = jnp.asarray(powsN, jnp.int32)

    def decode_one(bits):
        R = bits_to_symbols(bits, code.m)  # (n,)
        A = jnp.concatenate([mul(R[:, None], powsQ), powsN], axis=1)
        sol = _nullspace_masked(A, mul, inv)
        Q = sol[:nq]
        # Q(X_i) via Horner on fixed nq terms
        qx = jnp.zeros((n,), jnp.int32)
        for j in range(nq - 1, -1, -1):
            qx = jnp.bitwise_xor(mul(qx, xs), Q[j])
        err = (qx == 0) & (Q.any())  # if Q == 0, decoding failed
        # choose k error-free positions (stable: correct ones first)
        order = jnp.argsort(err.astype(jnp.int32), stable=True)
        sel = order[:k]
        P_at = _lagrange_eval(xs[sel], R[sel], xs, mul, inv)  # (n,)
        n_err = jnp.sum(P_at != R)
        ok = (n_err <= t) & Q.any()
        cw = jnp.where(ok, P_at, R)
        msg = cw[:k]
        return {"message_bits": symbols_to_bits(msg, code.m),
                "codeword_bits": symbols_to_bits(cw, code.m),
                "n_corrected": jnp.where(ok, n_err, -1),
                "ok": ok}

    return decode_one


def make_batch_decoder(code: RSCode):
    one = make_decoder(code)
    return jax.jit(jax.vmap(one))


def make_encoder(code: RSCode):
    """Batched systematic encoder (used by fine-tuning + benchmarks)."""
    exp, log, xs = _consts(code)
    q = 1 << code.m
    mul, inv = _mk_ops(exp, log, q)
    k, n = code.k, code.n

    def encode_one(message_bits):
        M = bits_to_symbols(message_bits, code.m)  # (k,)
        cw = _lagrange_eval(xs[:k], M, xs, mul, inv)
        cw = cw.at[:k].set(M)
        return symbols_to_bits(cw, code.m)

    return jax.jit(jax.vmap(encode_one))
