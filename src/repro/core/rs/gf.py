"""Galois field GF(2^m) arithmetic via log/exp tables.

Numpy implementation — the scalar reference for both the paper-faithful
CPU decoder and the batched JAX decoder (which reuses these tables as
device-side lookup arrays).
"""
from __future__ import annotations

import functools

import numpy as np

# primitive polynomials (with the x^m term) per field size
PRIM_POLY = {2: 0b111, 3: 0b1011, 4: 0b10011, 8: 0b100011101}


@functools.lru_cache(maxsize=None)
def tables(m: int):
    """Returns (exp, log): exp[i] = alpha^i (len 2^m-1, doubled for wrap),
    log[a] for a in 1..2^m-1 (log[0] = 0 sentinel, must be masked)."""
    poly = PRIM_POLY[m]
    q = 1 << m
    exp = np.zeros(2 * (q - 1), dtype=np.int32)
    log = np.zeros(q, dtype=np.int32)
    x = 1
    for i in range(q - 1):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & q:
            x ^= poly
    exp[q - 1:] = exp[: q - 1]  # wraparound so exp[i+j] needs no modulo
    return exp, log


class GF:
    """GF(2^m) scalar/vector ops on numpy int arrays."""

    def __init__(self, m: int):
        self.m = m
        self.q = 1 << m
        self.exp, self.log = tables(m)

    def add(self, a, b):
        return np.bitwise_xor(a, b)

    sub = add  # characteristic 2

    def mul(self, a, b):
        a = np.asarray(a, np.int32)
        b = np.asarray(b, np.int32)
        out = self.exp[(self.log[a] + self.log[b])]
        return np.where((a == 0) | (b == 0), 0, out)

    def inv(self, a):
        a = np.asarray(a, np.int32)
        if np.any(a == 0):
            raise ZeroDivisionError("GF inverse of 0")
        return self.exp[(self.q - 1 - self.log[a]) % (self.q - 1)]

    def div(self, a, b):
        return self.mul(a, self.inv(b))

    def pow(self, a, e):
        a = np.asarray(a, np.int32)
        e = int(e)
        if e == 0:
            return np.ones_like(a)
        out = self.exp[(self.log[a] * e) % (self.q - 1)]
        return np.where(a == 0, 0, out)

    # -- polynomials (coefficient lists, index = power) --------------------
    def poly_eval(self, coeffs, x):
        """Horner evaluation.  coeffs: (..., deg+1) lowest power first."""
        coeffs = np.asarray(coeffs, np.int32)
        x = np.asarray(x, np.int32)
        acc = np.zeros(np.broadcast(coeffs[..., 0], x).shape, np.int32)
        for i in range(coeffs.shape[-1] - 1, -1, -1):
            acc = self.add(self.mul(acc, x), coeffs[..., i])
        return acc

    def poly_mul(self, a, b):
        out = np.zeros(len(a) + len(b) - 1, np.int32)
        for i, ai in enumerate(a):
            out[i:i + len(b)] ^= self.mul(ai, np.asarray(b, np.int32))
        return out

    def poly_divmod(self, num, den):
        """Polynomial long division: returns (quotient, remainder)."""
        num = list(np.asarray(num, np.int32))
        den = np.asarray(den, np.int32)
        dd = len(den) - 1
        while dd > 0 and den[dd] == 0:
            dd -= 1
        if dd == 0 and den[0] == 0:
            raise ZeroDivisionError("poly division by zero")
        inv_lead = self.inv(den[dd])
        q = [0] * max(len(num) - dd, 1)
        for i in range(len(num) - 1 - dd, -1, -1):
            c = self.mul(num[i + dd], inv_lead)
            q[i] = int(c)
            if c:
                for j in range(dd + 1):
                    num[i + j] ^= int(self.mul(c, den[j]))
        return np.array(q, np.int32), np.array(num[:dd] if dd else [0],
                                               np.int32)


# -- bit <-> symbol packing (MSB-first within each m-bit symbol) ------------


def bits_to_symbols(bits, m):
    bits = np.asarray(bits).astype(np.int32).reshape(-1, m)
    weights = 1 << np.arange(m - 1, -1, -1)
    return bits @ weights


def symbols_to_bits(symbols, m):
    symbols = np.asarray(symbols, np.int32)
    shifts = np.arange(m - 1, -1, -1)
    return ((symbols[..., None] >> shifts) & 1).reshape(
        *symbols.shape[:-1], -1)
