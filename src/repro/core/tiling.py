"""Tile selection strategies (QRMark Table 1): random, random_grid, fixed.

All strategies are jit-able: tile extraction is a dynamic_slice so the
whole detection pipeline stays on device.  ``random_grid`` (the QRMark
default) partitions the image into an axis-aligned grid of l x l cells and
samples one cell uniformly; ``random`` samples any aligned-to-nothing
l x l window; ``fixed`` crops the top-left corner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STRATEGIES = ("random", "random_grid", "fixed")


def tile_offsets(strategy: str, key, image_hw, tile: int, batch: int):
    """Per-image (y, x) offsets, shape (batch, 2), int32."""
    H, W = image_hw
    if strategy == "fixed":
        return jnp.zeros((batch, 2), jnp.int32)
    if strategy == "random":
        ky, kx = jax.random.split(key)
        y = jax.random.randint(ky, (batch,), 0, H - tile + 1)
        x = jax.random.randint(kx, (batch,), 0, W - tile + 1)
        return jnp.stack([y, x], axis=1).astype(jnp.int32)
    if strategy == "random_grid":
        gy, gx = H // tile, W // tile
        k = jax.random.randint(key, (batch,), 0, gy * gx)
        y = (k // gx) * tile
        x = (k % gx) * tile
        return jnp.stack([y, x], axis=1).astype(jnp.int32)
    raise ValueError(f"unknown tiling strategy {strategy!r}")


def extract_tiles(images, offsets, tile: int):
    """images (b, H, W, C), offsets (b, 2) -> (b, tile, tile, C)."""

    def one(img, off):
        return jax.lax.dynamic_slice(
            img, (off[0], off[1], 0), (tile, tile, img.shape[-1]))

    return jax.vmap(one)(images, offsets)


def extract_tiles_k(images, plans, tile: int):
    """k-tile generalisation of :func:`extract_tiles`: images
    (b, H, W, C) + plans (b, k, 2) -> (b*k, tile, tile, C), image-major
    (rows [i*k, (i+1)*k) are image i's tiles — the layout of the
    ``(b, k, 2)`` tile-first kernel form, whose oracle and the staged
    escalation path both call this)."""
    b, k = plans.shape[:2]

    def one(img, offs):
        return jax.vmap(lambda o: jax.lax.dynamic_slice(
            img, (o[0], o[1], 0), (tile, tile, img.shape[-1])))(offs)

    tiles = jax.vmap(one)(images, jnp.asarray(plans, jnp.int32))
    return tiles.reshape(b * k, tile, tile, images.shape[-1])


def select_tiles(strategy: str, key, images, tile: int):
    b, H, W, _ = images.shape
    offs = tile_offsets(strategy, key, (H, W), tile, b)
    return extract_tiles(images, offs, tile), offs


def per_image_offsets(strategy: str, keys, image_hw, tile: int):
    """Like :func:`tile_offsets` but driven by one PRNG key per image
    (shape ``(b,)`` key array) instead of one batch-shaped draw.

    The offset for image i depends only on ``keys[i]`` — not on the
    batch size — so padding a ragged batch or sharding it across
    devices leaves every real image's tile choice bit-identical.  This
    is the form the lane executor and the sharded ``run_batch`` use."""
    H, W = image_hw
    if strategy == "fixed":
        b = keys.shape[0]
        return jnp.zeros((b, 2), jnp.int32)
    if strategy == "random":
        def one(k):
            ky, kx = jax.random.split(k)
            y = jax.random.randint(ky, (), 0, H - tile + 1)
            x = jax.random.randint(kx, (), 0, W - tile + 1)
            return jnp.stack([y, x]).astype(jnp.int32)
        return jax.vmap(one)(keys)
    if strategy == "random_grid":
        gy, gx = H // tile, W // tile

        def one(k):
            c = jax.random.randint(k, (), 0, gy * gx)
            return (jnp.stack([(c // gx), (c % gx)]) * tile).astype(
                jnp.int32)
        return jax.vmap(one)(keys)
    raise ValueError(f"unknown tiling strategy {strategy!r}")


def select_tiles_per_image(strategy: str, keys, images, tile: int):
    """Per-image-keyed variant of :func:`select_tiles`."""
    _, H, W, _ = images.shape
    offs = per_image_offsets(strategy, keys, (H, W), tile)
    return extract_tiles(images, offs, tile), offs


def tile_first_offsets(strategy: str, keys, *, img_size: int, tile: int):
    """Offsets for the tile-first ingest path.

    Tile choice depends only on the per-image PRNG key and the *static*
    preprocessed geometry (img_size x img_size), never on pixel data —
    so the offsets can be derived BEFORE ingest runs and handed to
    ``kernels.ops.fused_tile_preprocess``, which slices the interpolation
    matrices down to the selected tile's rows/columns instead of
    materialising the full preprocessed image.  Identical draws to
    :func:`per_image_offsets`, so the tile-first and staged paths pick
    the same tile for every image."""
    return per_image_offsets(strategy, keys, (img_size, img_size), tile)


# fold_in salt for the extra escalation tile draws: keeps columns 1..k-1
# statistically independent of the column-0 draw without disturbing it
_ESC_SALT = 0x5AFE


def max_escalation_tiles(strategy: str, image_hw, tile: int) -> int:
    """Largest usable ``k`` for :func:`escalation_offsets`.

    Grid-aligned strategies (``random_grid``, ``fixed``) cannot exceed
    the number of grid cells; ``random`` can draw any number of
    (possibly overlapping) windows."""
    H, W = image_hw
    if strategy in ("random_grid", "fixed"):
        return max(1, (H // tile) * (W // tile))
    return 2 ** 30


def escalation_offsets(strategy: str, keys, image_hw, tile: int, k: int):
    """Per-image k-tile escalation plans: ``(b, k, 2)`` int32 offsets
    driven by one PRNG key per image.

    The bit-identity contract: **column 0 equals**
    :func:`per_image_offsets` (and therefore
    :func:`tile_first_offsets`) **bit for bit** — escalation round 1
    decodes exactly the tile the single-tile pipeline picks, so a
    pipeline with ``escalate_tiles == 1`` and one with ``k > 1`` whose
    round-1 RS succeeds produce identical results.  Extra columns:

    * ``random_grid`` — the remaining grid cells in a per-image
      permuted order (``fold_in(key, salt)``-driven), so no cell is
      ever decoded twice for one image; requires ``k <= gy * gx``;
    * ``fixed`` — grid cells in raster order from the top-left
      (deterministic, distinct); requires ``k <= gy * gx``;
    * ``random`` — independent fresh draws from
      ``fold_in(key, salt + j)`` (the strategy permits overlapping
      windows by construction).

    Like every key-driven draw here, image i's plan depends only on
    ``keys[i]`` and the static geometry — never on batch size, padding,
    sharding, or pixel data — so escalation plans can be derived before
    ingest and are identical across every execution engine."""
    H, W = image_hw
    if k < 1:
        raise ValueError(f"escalation needs k >= 1, got {k}")
    cap = max_escalation_tiles(strategy, image_hw, tile)
    if k > cap:
        raise ValueError(
            f"strategy {strategy!r} on {H}x{W}/{tile} supports at most "
            f"{cap} distinct tiles, got k={k}")
    # column 0 is per_image_offsets' OWN output (not a re-derivation),
    # so the round-1 contract holds by construction even if the base
    # draw ever changes
    col0 = per_image_offsets(strategy, keys, image_hw, tile)
    if strategy == "fixed":
        b = keys.shape[0]
        gx = W // tile
        cells = jnp.arange(k, dtype=jnp.int32)
        offs = jnp.stack([cells // gx, cells % gx], axis=1) * tile
        plan = jnp.broadcast_to(offs[None], (b, k, 2)).astype(jnp.int32)
        return plan.at[:, 0].set(col0)   # == cell 0 today; by contract
    if strategy == "random":
        extra = [per_image_offsets(
                     strategy,
                     jax.vmap(lambda kk, j=j: jax.random.fold_in(
                         kk, _ESC_SALT + j))(keys),
                     image_hw, tile)
                 for j in range(1, k)]
        return jnp.stack([col0, *extra], axis=1)
    if strategy == "random_grid":
        gy, gx = H // tile, W // tile
        n_cells = gy * gx
        c0 = (col0[:, 0] // tile) * gx + col0[:, 1] // tile

        def rest(key, c0_i):
            perm = jax.random.permutation(
                jax.random.fold_in(key, _ESC_SALT), n_cells)
            # stable-compact c0 out of the permutation: jnp.argsort is
            # stable, so the non-c0 cells keep their permuted relative
            # order and c0 sinks to the end
            order = jnp.argsort((perm == c0_i).astype(jnp.int32))
            cells = perm[order][: k - 1]
            return (jnp.stack([cells // gx, cells % gx], axis=1)
                    * tile).astype(jnp.int32)

        if k == 1:
            return col0[:, None, :]
        extra = jax.vmap(rest)(keys, c0)
        return jnp.concatenate([col0[:, None, :], extra], axis=1)
    raise ValueError(f"unknown tiling strategy {strategy!r}")


def grid_partition(images, tile: int):
    """All non-overlapping l x l tiles: (b, gy*gx, tile, tile, C)."""
    b, H, W, C = images.shape
    gy, gx = H // tile, W // tile
    x = images[:, : gy * tile, : gx * tile]
    x = x.reshape(b, gy, tile, gx, tile, C).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gy * gx, tile, tile, C)
