"""Tile selection strategies (QRMark Table 1): random, random_grid, fixed.

All strategies are jit-able: tile extraction is a dynamic_slice so the
whole detection pipeline stays on device.  ``random_grid`` (the QRMark
default) partitions the image into an axis-aligned grid of l x l cells and
samples one cell uniformly; ``random`` samples any aligned-to-nothing
l x l window; ``fixed`` crops the top-left corner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

STRATEGIES = ("random", "random_grid", "fixed")


def tile_offsets(strategy: str, key, image_hw, tile: int, batch: int):
    """Per-image (y, x) offsets, shape (batch, 2), int32."""
    H, W = image_hw
    if strategy == "fixed":
        return jnp.zeros((batch, 2), jnp.int32)
    if strategy == "random":
        ky, kx = jax.random.split(key)
        y = jax.random.randint(ky, (batch,), 0, H - tile + 1)
        x = jax.random.randint(kx, (batch,), 0, W - tile + 1)
        return jnp.stack([y, x], axis=1).astype(jnp.int32)
    if strategy == "random_grid":
        gy, gx = H // tile, W // tile
        k = jax.random.randint(key, (batch,), 0, gy * gx)
        y = (k // gx) * tile
        x = (k % gx) * tile
        return jnp.stack([y, x], axis=1).astype(jnp.int32)
    raise ValueError(f"unknown tiling strategy {strategy!r}")


def extract_tiles(images, offsets, tile: int):
    """images (b, H, W, C), offsets (b, 2) -> (b, tile, tile, C)."""

    def one(img, off):
        return jax.lax.dynamic_slice(
            img, (off[0], off[1], 0), (tile, tile, img.shape[-1]))

    return jax.vmap(one)(images, offsets)


def select_tiles(strategy: str, key, images, tile: int):
    b, H, W, _ = images.shape
    offs = tile_offsets(strategy, key, (H, W), tile, b)
    return extract_tiles(images, offs, tile), offs


def per_image_offsets(strategy: str, keys, image_hw, tile: int):
    """Like :func:`tile_offsets` but driven by one PRNG key per image
    (shape ``(b,)`` key array) instead of one batch-shaped draw.

    The offset for image i depends only on ``keys[i]`` — not on the
    batch size — so padding a ragged batch or sharding it across
    devices leaves every real image's tile choice bit-identical.  This
    is the form the lane executor and the sharded ``run_batch`` use."""
    H, W = image_hw
    if strategy == "fixed":
        b = keys.shape[0]
        return jnp.zeros((b, 2), jnp.int32)
    if strategy == "random":
        def one(k):
            ky, kx = jax.random.split(k)
            y = jax.random.randint(ky, (), 0, H - tile + 1)
            x = jax.random.randint(kx, (), 0, W - tile + 1)
            return jnp.stack([y, x]).astype(jnp.int32)
        return jax.vmap(one)(keys)
    if strategy == "random_grid":
        gy, gx = H // tile, W // tile

        def one(k):
            c = jax.random.randint(k, (), 0, gy * gx)
            return (jnp.stack([(c // gx), (c % gx)]) * tile).astype(
                jnp.int32)
        return jax.vmap(one)(keys)
    raise ValueError(f"unknown tiling strategy {strategy!r}")


def select_tiles_per_image(strategy: str, keys, images, tile: int):
    """Per-image-keyed variant of :func:`select_tiles`."""
    _, H, W, _ = images.shape
    offs = per_image_offsets(strategy, keys, (H, W), tile)
    return extract_tiles(images, offs, tile), offs


def tile_first_offsets(strategy: str, keys, *, img_size: int, tile: int):
    """Offsets for the tile-first ingest path.

    Tile choice depends only on the per-image PRNG key and the *static*
    preprocessed geometry (img_size x img_size), never on pixel data —
    so the offsets can be derived BEFORE ingest runs and handed to
    ``kernels.ops.fused_tile_preprocess``, which slices the interpolation
    matrices down to the selected tile's rows/columns instead of
    materialising the full preprocessed image.  Identical draws to
    :func:`per_image_offsets`, so the tile-first and staged paths pick
    the same tile for every image."""
    return per_image_offsets(strategy, keys, (img_size, img_size), tile)


def grid_partition(images, tile: int):
    """All non-overlapping l x l tiles: (b, gy*gx, tile, tile, C)."""
    b, H, W, C = images.shape
    gy, gx = H // tile, W // tile
    x = images[:, : gy * tile, : gx * tile]
    x = x.reshape(b, gy, tile, gx, tile, C).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, gy * gx, tile, tile, C)
