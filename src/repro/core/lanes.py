"""Multi-lane horizontal-fusion executor (QRMark §6.2, system layer).

The paper's resource-aware multi-channel horizontal fusion assigns more
CUDA streams to GPU-intensive pipeline stages.  The host-side analogue
implemented here is an explicit *stage graph*: each detection stage
(ingest/preprocess, tiled decode, RS correction) is a :class:`Stage`
with a declared resource profile, and :class:`LaneExecutor` runs the
allocator's lane assignment as real concurrency — ``lanes[k]`` worker
threads per stage k, connected by bounded queues, with multiple
mini-batches in flight per stage.  Stage functions that dispatch jitted
JAX computations return *futures* (async dispatch), so a downstream
stage enqueues device work while upstream lanes keep feeding — the
N-lane generalisation of the 2-deep ``PrefetchIterator`` this module
replaces (``interleave.PrefetchIterator`` is now a single-stage
``LaneExecutor``).

Correctness contract: results come out in *input order* regardless of
lane count, and stage functions are pure w.r.t. their payload (all RNG
keys are pre-derived from the item's sequence number), so any lane
configuration is bit-identical to serial execution of the same stage
functions.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence


@dataclasses.dataclass
class Stage:
    """One node of the detection stage graph.

    ``fn`` maps payload -> payload.  ``lanes`` is the number of worker
    threads (concurrent mini-batches in flight for this stage); ``depth``
    bounds the stage's input queue.  ``gpu_intensive`` records the
    resource profile the allocator uses to decide who gets extra lanes
    (Algorithm 1 gives device-bound stages more streams, host-bound
    stages fewer)."""
    name: str
    fn: Callable[[Any], Any]
    lanes: int = 1
    depth: int = 2
    gpu_intensive: bool = False
    profile: Optional[object] = None   # allocator.StageProfile when known

    def __post_init__(self):
        if self.lanes < 1:
            raise ValueError(f"stage {self.name!r}: lanes must be >= 1")
        if self.depth < 1:
            raise ValueError(f"stage {self.name!r}: depth must be >= 1")


class _Failure:
    """Error marker that flows through the graph in place of a payload so
    ordering never stalls; re-raised at the consumer in sequence order."""

    def __init__(self, err: BaseException):
        self.err = err


_DONE = object()


class LaneExecutor:
    """Runs a linear stage graph over a stream of items.

    * one input queue per stage, ``maxsize = stage.depth`` — bounded
      buffering is what overlaps the stages without unbounded memory;
    * ``stage.lanes`` daemon worker threads per stage — horizontal
      fusion: several mini-batches of the *same* stage in flight;
    * a reorder buffer at the sink restores input order, so lane count
      never changes observable results.
    """

    def __init__(self, stages: Sequence[Stage], name: str = "pipeline"):
        if not stages:
            raise ValueError("LaneExecutor needs at least one stage")
        self.stages = list(stages)
        self.name = name
        self._cancel = threading.Event()
        self._used = False

    # -- cooperative queue ops so close() can unstick blocked workers ----
    def _put(self, q: "queue.Queue", item) -> bool:
        while not self._cancel.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _get(self, q: "queue.Queue"):
        while not self._cancel.is_set():
            try:
                return q.get(timeout=0.05)
            except queue.Empty:
                continue
        return _DONE

    def close(self):
        """Cancel in-flight work (workers drain and exit)."""
        self._cancel.set()

    # ------------------------------------------------------------------
    def run(self, items: Iterable) -> Iterator:
        """Pump ``items`` through the graph; yields results in order.

        Single-use: the sink cancels all workers when the stream ends,
        so a second ``run()`` needs a fresh executor."""
        if self._used:
            raise RuntimeError(
                f"{self.name}: LaneExecutor.run() is single-use — "
                "construct a new executor for another stream")
        self._used = True
        qs = [queue.Queue(maxsize=s.depth) for s in self.stages]
        # the sink queue is bounded too: a slow consumer must exert
        # backpressure on the whole graph, not buffer the entire stream
        out_q: "queue.Queue" = queue.Queue(maxsize=self.stages[-1].depth)

        def feeder():
            seq = 0
            try:
                for item in items:
                    if not self._put(qs[0], (seq, item)):
                        return
                    seq += 1
            except BaseException as e:  # source iterator failed: the
                # error takes the next sequence slot so every item fed
                # before it still comes out first
                self._put(qs[0], (seq, _Failure(e)))
            finally:
                self._put(qs[0], _DONE)

        def worker(idx: int, stage: Stage, done_box: dict):
            in_q = qs[idx]
            nxt = qs[idx + 1] if idx + 1 < len(qs) else out_q
            while True:
                got = self._get(in_q)
                if got is _DONE:
                    with done_box["lock"]:
                        done_box["n"] += 1
                        last = done_box["n"] >= stage.lanes
                    # siblings each need to see the sentinel once; the
                    # last lane forwards it downstream instead
                    self._put(nxt if last else in_q, _DONE)
                    return
                seq, payload = got
                if isinstance(payload, _Failure):
                    self._put(nxt, (seq, payload))
                    continue
                try:
                    payload = stage.fn(payload)
                except BaseException as e:
                    payload = _Failure(e)
                self._put(nxt, (seq, payload))

        threads = [threading.Thread(target=feeder, daemon=True,
                                    name=f"{self.name}/feed")]
        for i, st in enumerate(self.stages):
            box = {"lock": threading.Lock(), "n": 0}
            for lane in range(st.lanes):
                threads.append(threading.Thread(
                    target=worker, args=(i, st, box), daemon=True,
                    name=f"{self.name}/{st.name}.{lane}"))
        for t in threads:
            t.start()

        # sink: reorder buffer keyed by sequence number.  The sentinel
        # protocol guarantees _DONE reaches out_q only after every
        # result (each lane finishes + forwards its in-flight item
        # before consuming the sentinel), so draining until _DONE then
        # flushing the buffer sees every sequence number exactly once.
        buf: Dict[int, Any] = {}
        next_seq = 0
        done = False
        try:
            while not done or buf:
                if not done:
                    got = self._get(out_q)
                    if got is _DONE:
                        done = True
                        continue
                    seq, payload = got
                    buf[seq] = payload
                while next_seq in buf:
                    payload = buf.pop(next_seq)
                    next_seq += 1
                    if isinstance(payload, _Failure):
                        raise payload.err
                    yield payload
                if done and buf and next_seq not in buf:
                    raise RuntimeError(
                        f"{self.name}: lost sequence {next_seq} "
                        f"(have {sorted(buf)})")
        finally:
            self.close()

    def map(self, items: Iterable) -> List:
        """Eager form of :meth:`run`."""
        return list(self.run(items))


def lanes_from_allocation(stage_names: Sequence[str],
                          streams: Sequence[int]) -> Dict[str, int]:
    """{stage: lanes} from an ``allocator.Allocation.streams`` vector."""
    return {n: max(1, int(s)) for n, s in zip(stage_names, streams)}
