"""Multi-lane horizontal-fusion executor (QRMark §6.2, system layer).

The paper's resource-aware multi-channel horizontal fusion assigns more
CUDA streams to GPU-intensive pipeline stages.  The host-side analogue
implemented here is an explicit *stage graph*: each detection stage
(ingest/preprocess, tiled decode, RS correction) is a :class:`Stage`
with a declared resource profile, and :class:`LaneExecutor` runs the
allocator's lane assignment as real concurrency — ``lanes[k]`` worker
threads per stage k, connected by bounded queues, with multiple
mini-batches in flight per stage.  Stage functions that dispatch jitted
JAX computations return *futures* (async dispatch), so a downstream
stage enqueues device work while upstream lanes keep feeding — the
N-lane generalisation of the 2-deep ``PrefetchIterator`` this module
replaces (``interleave.PrefetchIterator`` is now a single-stage
``LaneExecutor``).

Correctness contract: results come out in *input order* regardless of
lane count, and stage functions are pure w.r.t. their payload (all RNG
keys are pre-derived from the item's sequence number), so any lane
configuration is bit-identical to serial execution of the same stage
functions.

Two execution modes share the same worker machinery:

* :meth:`LaneExecutor.run` — the original single-use, stream-terminated
  generator (offline batch jobs: the whole input is known up front and
  results are consumed in order);
* **service mode** (:meth:`LaneExecutor.start`) — a long-lived executor
  for online serving: :meth:`submit` enqueues one payload and returns a
  :class:`Ticket` (a future), completions are delivered *out of order*
  as they finish (per-ticket callback + ``Ticket.result()``),
  :meth:`drain` waits for in-flight work, :meth:`close` shuts down, and
  :meth:`reconfigure` re-applies a new lane allocation *live* — workers
  are added or retired without dropping queued work, so Algorithm 1 can
  be re-run online when measured stage latencies drift from warmup.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, \
    Sequence


@dataclasses.dataclass
class Stage:
    """One node of the detection stage graph.

    ``fn`` maps payload -> payload.  ``lanes`` is the number of worker
    threads (concurrent mini-batches in flight for this stage); ``depth``
    bounds the stage's input queue.  ``gpu_intensive`` records the
    resource profile the allocator uses to decide who gets extra lanes
    (Algorithm 1 gives device-bound stages more streams, host-bound
    stages fewer)."""
    name: str
    fn: Callable[[Any], Any]
    lanes: int = 1
    depth: int = 2
    gpu_intensive: bool = False
    profile: Optional[object] = None   # allocator.StageProfile when known

    def __post_init__(self):
        if self.lanes < 1:
            raise ValueError(f"stage {self.name!r}: lanes must be >= 1")
        if self.depth < 1:
            raise ValueError(f"stage {self.name!r}: depth must be >= 1")


class _Failure:
    """Error marker that flows through the graph in place of a payload so
    ordering never stalls; re-raised at the consumer in sequence order."""

    def __init__(self, err: BaseException):
        self.err = err


_DONE = object()


class _Retire:
    """Poison token for live lane removal: the service worker that pops
    it exits instead of processing — queued payloads behind it keep
    flowing through the stage's remaining lanes."""


class Ticket:
    """Future for one payload submitted to a service-mode executor.

    Resolved (out of input order — completion order) by the dispatcher
    thread; ``result()`` re-raises the stage error if the payload
    failed."""

    def __init__(self, seq: int):
        self.seq = seq
        self._ready = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._ready.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._ready.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not done after "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        if not self._ready.wait(timeout):
            raise TimeoutError(f"ticket {self.seq} not done after "
                               f"{timeout}s")
        return self._error

    def _resolve(self, value):
        self._value = value
        self._ready.set()

    def _reject(self, err: BaseException):
        self._error = err
        self._ready.set()


class LaneExecutor:
    """Runs a linear stage graph over a stream of items.

    * one input queue per stage, ``maxsize = stage.depth`` — bounded
      buffering is what overlaps the stages without unbounded memory;
    * ``stage.lanes`` daemon worker threads per stage — horizontal
      fusion: several mini-batches of the *same* stage in flight;
    * a reorder buffer at the sink restores input order, so lane count
      never changes observable results.
    """

    def __init__(self, stages: Sequence[Stage], name: str = "pipeline"):
        if not stages:
            raise ValueError("LaneExecutor needs at least one stage")
        self.stages = list(stages)
        self.name = name
        self._cancel = threading.Event()
        self._used = False
        # service-mode state (populated by start())
        self._service = False
        self._closed = False
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._tickets: Dict[int, tuple] = {}   # seq -> (Ticket, callback)
        self._submit_seq = 0
        self._service_threads: List[threading.Thread] = []
        self._lane_counts: Dict[str, int] = {}

    # -- cooperative queue ops so close() can unstick blocked workers ----
    def _put(self, q: "queue.Queue", item) -> bool:
        while not self._cancel.is_set():
            try:
                q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _get(self, q: "queue.Queue"):
        while not self._cancel.is_set():
            try:
                return q.get(timeout=0.05)
            except queue.Empty:
                continue
        return _DONE

    def close(self):
        """Cancel in-flight work (workers drain and exit).  In service
        mode also rejects every unresolved ticket so no caller blocks on
        a result that will never arrive; call :meth:`drain` first for a
        graceful shutdown."""
        with self._lock:
            self._closed = True
            pending = list(self._tickets.values())
            self._tickets.clear()
            self._idle.notify_all()
        self._cancel.set()
        for ticket, callback in pending:
            self._deliver_rejection(ticket, callback)
        # join service threads: cancelled workers exit within one poll
        # interval, and leaving them alive into interpreter shutdown
        # aborts the process when the runtime's C++ state is torn down
        # under a thread mid-teardown
        me = threading.current_thread()
        for t in self._service_threads:
            if t is not me:
                t.join(timeout=2.0)

    # ------------------------------------------------------------------
    # service mode: long-lived submit/complete executor
    # ------------------------------------------------------------------
    def start(self) -> "LaneExecutor":
        """Switch to long-lived service mode.

        Spawns the stage workers and a dispatcher thread; payloads enter
        via :meth:`submit` and leave through their :class:`Ticket` (and
        optional callback) in *completion* order — the reorder buffer of
        :meth:`run` is the caller's concern here (an online server wants
        each result the moment it exists, not after its predecessors)."""
        if self._used:
            raise RuntimeError(
                f"{self.name}: executor already used (run() and start() "
                "are mutually exclusive, one lifecycle per executor)")
        self._used = True
        self._service = True
        self._qs = [queue.Queue(maxsize=s.depth) for s in self.stages]
        self._out_q: "queue.Queue" = queue.Queue(
            maxsize=self.stages[-1].depth)
        for i, st in enumerate(self.stages):
            self._lane_counts[st.name] = st.lanes
            for lane in range(st.lanes):
                self._spawn_service_worker(i, lane)
        disp = threading.Thread(target=self._dispatch_loop, daemon=True,
                                name=f"{self.name}/dispatch")
        disp.start()
        self._service_threads.append(disp)
        return self

    def _spawn_service_worker(self, idx: int, lane: int):
        t = threading.Thread(
            target=self._service_worker, args=(idx,), daemon=True,
            name=f"{self.name}/{self.stages[idx].name}.{lane}")
        t.start()
        self._service_threads.append(t)

    def _service_worker(self, idx: int):
        stage = self.stages[idx]
        in_q = self._qs[idx]
        nxt = self._qs[idx + 1] if idx + 1 < len(self._qs) else self._out_q
        while True:
            got = self._get(in_q)
            if got is _DONE:          # cancelled
                return
            if isinstance(got, _Retire):   # live lane removal
                return
            seq, payload = got
            if not isinstance(payload, _Failure):
                try:
                    payload = stage.fn(payload)
                except BaseException as e:
                    payload = _Failure(e)
            self._put(nxt, (seq, payload))

    def _deliver_rejection(self, ticket: Ticket, callback):
        """Reject a ticket AND fire its callback: completion callbacks
        are the only notification some callers have (the server's
        result scatter), so a close()-time rejection that skipped them
        would leave those callers blocked forever."""
        ticket._reject(RuntimeError(f"{self.name}: executor closed"))
        if callback is not None:
            try:
                callback(ticket)
            except BaseException:
                pass

    def _dispatch_loop(self):
        """Sink for service mode: resolve tickets in completion order."""
        while True:
            got = self._get(self._out_q)
            if got is _DONE:          # cancelled
                return
            seq, payload = got
            with self._lock:
                entry = self._tickets.pop(seq, None)
                if not self._tickets:
                    self._idle.notify_all()
            if entry is None:         # closed under us; ticket rejected
                continue
            ticket, callback = entry
            if isinstance(payload, _Failure):
                ticket._reject(payload.err)
            else:
                ticket._resolve(payload)
            if callback is not None:
                try:
                    callback(ticket)
                except BaseException:
                    pass              # callbacks must not kill the sink

    def submit(self, payload, *,
               callback: Optional[Callable[[Ticket], None]] = None
               ) -> Ticket:
        """Enqueue one payload; returns its :class:`Ticket`.

        Blocks while the first stage queue is full — the executor's
        bounded queues are the backpressure surface (admission control
        with a hard depth bound lives in the caller, e.g. the
        micro-batcher).  ``callback(ticket)`` fires on the dispatcher
        thread the moment the payload completes (out of order)."""
        if not self._service:
            raise RuntimeError(f"{self.name}: submit() requires service "
                               "mode — call start() first")
        with self._lock:
            if self._closed:
                raise RuntimeError(f"{self.name}: executor closed")
            seq = self._submit_seq
            self._submit_seq += 1
            ticket = Ticket(seq)
            self._tickets[seq] = (ticket, callback)
        if not self._put(self._qs[0], (seq, payload)):
            with self._lock:
                entry = self._tickets.pop(seq, None)
                if not self._tickets:
                    self._idle.notify_all()
            if entry is not None:    # close() didn't already reject it
                self._deliver_rejection(ticket, callback)
            return ticket
        return ticket

    def pending(self) -> int:
        """Number of submitted-but-unresolved payloads."""
        with self._lock:
            return len(self._tickets)

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted payload has been delivered (or
        ``timeout`` elapses).  Returns True when idle."""
        with self._idle:
            return self._idle.wait_for(
                lambda: not self._tickets or self._closed, timeout)

    def reconfigure(self, lanes: Dict[str, int]) -> Dict[str, int]:
        """Re-apply a lane allocation to a *running* service executor.

        Growing a stage spawns workers immediately; shrinking enqueues
        retire tokens that the next free worker of that stage consumes —
        queued payloads are never dropped, and results stay bit-identical
        because stage fns are pure.  Returns the new lane map."""
        if not self._service:
            raise RuntimeError(f"{self.name}: reconfigure() requires "
                               "service mode")
        retire: List[int] = []     # stage indices, one entry per token
        with self._lock:
            if self._closed:
                raise RuntimeError(f"{self.name}: executor closed")
            for i, st in enumerate(self.stages):
                target = lanes.get(st.name)
                if target is None:
                    continue
                target = max(1, int(target))
                cur = self._lane_counts[st.name]
                if target > cur:
                    for lane in range(cur, target):
                        self._spawn_service_worker(i, lane)
                elif target < cur:
                    retire.extend([i] * (cur - target))
                self._lane_counts[st.name] = target
                st.lanes = target
            out = dict(self._lane_counts)
        # retire tokens ride the bounded stage queues; putting them
        # outside the lock keeps the dispatcher free to drain results
        # (the queues only empty while the sink keeps consuming)
        for i in retire:
            self._put(self._qs[i], _Retire())
        return out

    def lane_counts(self) -> Dict[str, int]:
        """Current {stage: lanes} (live, reflects reconfigure())."""
        if self._service:
            with self._lock:
                return dict(self._lane_counts)
        return {s.name: s.lanes for s in self.stages}

    # ------------------------------------------------------------------
    def run(self, items: Iterable) -> Iterator:
        """Pump ``items`` through the graph; yields results in order.

        Single-use: the sink cancels all workers when the stream ends,
        so a second ``run()`` needs a fresh executor."""
        if self._used:
            raise RuntimeError(
                f"{self.name}: LaneExecutor.run() is single-use — "
                "construct a new executor for another stream")
        self._used = True
        qs = [queue.Queue(maxsize=s.depth) for s in self.stages]
        # the sink queue is bounded too: a slow consumer must exert
        # backpressure on the whole graph, not buffer the entire stream
        out_q: "queue.Queue" = queue.Queue(maxsize=self.stages[-1].depth)

        def feeder():
            seq = 0
            try:
                for item in items:
                    if not self._put(qs[0], (seq, item)):
                        return
                    seq += 1
            except BaseException as e:  # source iterator failed: the
                # error takes the next sequence slot so every item fed
                # before it still comes out first
                self._put(qs[0], (seq, _Failure(e)))
            finally:
                self._put(qs[0], _DONE)

        def worker(idx: int, stage: Stage, done_box: dict):
            in_q = qs[idx]
            nxt = qs[idx + 1] if idx + 1 < len(qs) else out_q
            while True:
                got = self._get(in_q)
                if got is _DONE:
                    with done_box["lock"]:
                        done_box["n"] += 1
                        last = done_box["n"] >= stage.lanes
                    # siblings each need to see the sentinel once; the
                    # last lane forwards it downstream instead
                    self._put(nxt if last else in_q, _DONE)
                    return
                seq, payload = got
                if isinstance(payload, _Failure):
                    self._put(nxt, (seq, payload))
                    continue
                try:
                    payload = stage.fn(payload)
                except BaseException as e:
                    payload = _Failure(e)
                self._put(nxt, (seq, payload))

        threads = [threading.Thread(target=feeder, daemon=True,
                                    name=f"{self.name}/feed")]
        for i, st in enumerate(self.stages):
            box = {"lock": threading.Lock(), "n": 0}
            for lane in range(st.lanes):
                threads.append(threading.Thread(
                    target=worker, args=(i, st, box), daemon=True,
                    name=f"{self.name}/{st.name}.{lane}"))
        for t in threads:
            t.start()

        # sink: reorder buffer keyed by sequence number.  The sentinel
        # protocol guarantees _DONE reaches out_q only after every
        # result (each lane finishes + forwards its in-flight item
        # before consuming the sentinel), so draining until _DONE then
        # flushing the buffer sees every sequence number exactly once.
        buf: Dict[int, Any] = {}
        next_seq = 0
        done = False
        try:
            while not done or buf:
                if not done:
                    got = self._get(out_q)
                    if got is _DONE:
                        done = True
                        continue
                    seq, payload = got
                    buf[seq] = payload
                while next_seq in buf:
                    payload = buf.pop(next_seq)
                    next_seq += 1
                    if isinstance(payload, _Failure):
                        raise payload.err
                    yield payload
                if done and buf and next_seq not in buf:
                    raise RuntimeError(
                        f"{self.name}: lost sequence {next_seq} "
                        f"(have {sorted(buf)})")
        finally:
            self.close()

    def map(self, items: Iterable) -> List:
        """Eager form of :meth:`run`."""
        return list(self.run(items))


def lanes_from_allocation(stage_names: Sequence[str],
                          streams: Sequence[int]) -> Dict[str, int]:
    """{stage: lanes} from an ``allocator.Allocation.streams`` vector."""
    return {n: max(1, int(s)) for n, s in zip(stage_names, streams)}
