"""Inter-Batch Workload Interleaving (QRMark §6.1, RAP-style).

Each input batch B_k splits into a host *preparation region* P_k (decode /
layout / device placement) and a device *kernel region* K_k.  While the
device runs K_k, a background thread prepares P_{k+1}; JAX's async
dispatch then overlaps the host->device transfer and kernel execution.

``PrefetchIterator`` is the single-stage special case of the N-lane
stage-graph executor in :mod:`repro.core.lanes` — one "prepare" stage,
one lane, a depth-deep bounded queue — kept as the convenience wrapper
both the detection pipeline and the LM training input pipeline use.
"""
from __future__ import annotations

from typing import Callable, Iterable, Iterator, Optional

import jax

from repro.core.lanes import LaneExecutor, Stage


class PrefetchIterator:
    """Wrap an iterator of host batches; prepare + device_put ahead."""

    def __init__(self, it: Iterable, prepare: Optional[Callable] = None,
                 depth: int = 2, device_put: bool = True):
        prep = prepare or (lambda x: x)

        def fn(item):
            out = prep(item)
            if device_put:
                out = jax.device_put(out)
            return out

        self._ex = LaneExecutor(
            [Stage("prefetch", fn, lanes=1, depth=depth)], name="prefetch")
        self._gen = self._ex.run(it)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return next(self._gen)

    def close(self):
        self._ex.close()


def interleaved(it, prepare=None, depth: int = 2, enabled: bool = True):
    """Convenience: returns a prefetching iterator (or passthrough)."""
    if not enabled:
        return iter((prepare or (lambda x: x))(b) for b in it)
    return PrefetchIterator(it, prepare=prepare, depth=depth)
