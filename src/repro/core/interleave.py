"""Inter-Batch Workload Interleaving (QRMark §6.1, RAP-style).

Each input batch B_k splits into a host *preparation region* P_k (decode /
layout / device placement) and a device *kernel region* K_k.  While the
device runs K_k, a background thread prepares P_{k+1}; JAX's async
dispatch then overlaps the host->device transfer and kernel execution.
Implemented as a bounded-queue prefetcher usable by both the detection
pipeline and the LM training input pipeline.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterable, Iterator, Optional

import jax


class PrefetchIterator:
    """Wrap an iterator of host batches; prepare + device_put ahead."""

    def __init__(self, it: Iterable, prepare: Optional[Callable] = None,
                 depth: int = 2, device_put: bool = True):
        self._it = iter(it)
        self._prepare = prepare or (lambda x: x)
        self._device_put = device_put
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for item in self._it:
                out = self._prepare(item)
                if self._device_put:
                    out = jax.device_put(out)
                self._q.put(out)
        except BaseException as e:  # surface in consumer
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def interleaved(it, prepare=None, depth: int = 2, enabled: bool = True):
    """Convenience: returns a prefetching iterator (or passthrough)."""
    if not enabled:
        return iter((prepare or (lambda x: x))(b) for b in it)
    return PrefetchIterator(it, prepare=prepare, depth=depth)
