"""Algorithm 2 — Resource-aware mini-batch scheduling (QRMark §6.2) with
LPT placement, balance slack, shard-to-b_min fallback, and (beyond paper)
straggler mitigation for the 1000-node regime.

Tasks are tile-decoding work items; lanes are the executors produced by
the adaptive allocator.  The scheduler is execution-agnostic: it emits a
``Schedule`` that the pipeline runner maps onto lanes (threads driving
async device dispatch here; device groups on a real pod).
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Task:
    task_id: int
    n_samples: int
    tile: int
    lat: float              # predicted latency (warm-up model)
    mem: float              # predicted bytes
    minibatch: int = 0      # assigned by Step 4


@dataclasses.dataclass
class Schedule:
    lanes: List[List[Task]]
    m_unit: int
    loads: List[float]

    @property
    def imbalance(self) -> float:
        mx, mn = max(self.loads), min(self.loads)
        return mx / mn if mn > 0 else float("inf")


def predict_from_warmup(tile: int, stats: Dict[int, Tuple[float, float]],
                        n_samples: int, b0: int) -> Tuple[float, float]:
    """(latency, memory) for a task, interpolating warm-up stats.

    stats: {tile_size: (t_per_sample, bytes_per_sample)} measured at b0.
    Unknown tile sizes interpolate quadratically in tile area (decode cost
    scales with pixels)."""
    if tile in stats:
        t, u = stats[tile]
    else:
        base_tile, (bt, bu) = sorted(stats.items())[0]
        scale = (tile / base_tile) ** 2
        t, u = bt * scale, bu * scale
    return t * n_samples, u * n_samples


def lpt_schedule(tasks: Sequence[Task], *, n_lanes: int, balance_slack: float,
                 mem_cap: float, b_min: int, global_batch: int) -> Schedule:
    """Algorithm 2, faithful: LPT + balance check + shard fallback."""
    pool = sorted(tasks, key=lambda t: -t.lat)
    lanes: List[List[Task]] = [[] for _ in range(n_lanes)]
    loads = [0.0] * n_lanes
    mem_used = 0.0

    # max-latency-first pop; min-load lane; balance + memory constraints
    heap = [(-t.lat, i, t) for i, t in enumerate(pool)]
    heapq.heapify(heap)
    next_id = len(pool)
    while heap:
        _, _, kappa = heapq.heappop(heap)
        p_star = min(range(n_lanes), key=lambda p: loads[p])
        min_load = min(loads)
        bal_ok = loads[p_star] + kappa.lat <= (1 + balance_slack) * \
            max(min_load, kappa.lat)
        fit_ok = mem_used + kappa.mem <= mem_cap
        if (bal_ok and fit_ok) or kappa.n_samples <= b_min:
            lanes[p_star].append(kappa)
            loads[p_star] += kappa.lat
            mem_used += kappa.mem
        else:
            # shard kappa at granularity b_min
            n1 = max(b_min, kappa.n_samples // 2)
            n2 = kappa.n_samples - n1
            frac = n1 / kappa.n_samples
            k1 = dataclasses.replace(kappa, n_samples=n1,
                                     lat=kappa.lat * frac,
                                     mem=kappa.mem * frac)
            lanes[p_star].append(k1)
            loads[p_star] += k1.lat
            mem_used += k1.mem
            if n2 > 0:
                k2 = dataclasses.replace(
                    kappa, task_id=next_id, n_samples=n2,
                    lat=kappa.lat * (1 - frac), mem=kappa.mem * (1 - frac))
                next_id += 1
                heapq.heappush(heap, (-k2.lat, next_id, k2))

    # Step 4: uniform mini-batch size
    u = sum(len(l) for l in lanes)
    m_unit = max(b_min, global_batch // max(u, 1))
    for lane in lanes:
        for t in lane:
            t.minibatch = m_unit
    return Schedule(lanes, m_unit, loads)


def build_tasks(images_meta: Sequence[dict],
                warmup_stats: Dict[int, Tuple[float, float]], *,
                b0: int, select_tile: Callable[[dict], int],
                group: int = 1) -> List[Task]:
    """Step 1 of Algorithm 2: candidate task pool from an image set."""
    tasks = []
    for i in range(0, len(images_meta), group):
        metas = images_meta[i: i + group]
        tile = select_tile(metas[0])
        lat, mem = predict_from_warmup(tile, warmup_stats, len(metas), b0)
        tasks.append(Task(task_id=len(tasks), n_samples=len(metas),
                          tile=tile, lat=lat, mem=mem))
    return tasks


# ---------------------------------------------------------------------------
# straggler mitigation (beyond paper — required at 1000-node scale)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StragglerPolicy:
    timeout_factor: float = 3.0   # x median task latency
    min_timeout_s: float = 0.05
    max_retries: int = 2


class StragglerMonitor:
    """Tracks per-task start times; re-issues work that exceeds the
    timeout to the least-loaded healthy lane (speculative re-execution —
    first completion wins, duplicates are dropped by task_id)."""

    def __init__(self, policy: StragglerPolicy = StragglerPolicy()):
        self.policy = policy
        self._started: Dict[int, float] = {}
        self._done: set = set()
        self._retries: Dict[int, int] = {}
        self._latencies: List[float] = []

    def start(self, task_id: int):
        self._started[task_id] = time.perf_counter()

    def complete(self, task_id: int) -> bool:
        """Returns False if this was a duplicate completion."""
        if task_id in self._done:
            return False
        self._done.add(task_id)
        t0 = self._started.pop(task_id, None)
        if t0 is not None:
            self._latencies.append(time.perf_counter() - t0)
        return True

    def timeout_s(self) -> float:
        if not self._latencies:
            return self.policy.min_timeout_s
        med = sorted(self._latencies)[len(self._latencies) // 2]
        return max(self.policy.min_timeout_s,
                   self.policy.timeout_factor * med)

    def stragglers(self) -> List[int]:
        now = time.perf_counter()
        lim = self.timeout_s()
        out = []
        for tid, t0 in self._started.items():
            if now - t0 > lim and \
                    self._retries.get(tid, 0) < self.policy.max_retries:
                out.append(tid)
        return out

    def mark_retried(self, task_id: int):
        self._retries[task_id] = self._retries.get(task_id, 0) + 1
        self._started[task_id] = time.perf_counter()

    @property
    def retry_count(self) -> int:
        """Total speculative re-executions recorded via
        :meth:`mark_retried` — the number a service report should
        surface as ``straggler_retries``."""
        return sum(self._retries.values())
