"""End-to-end watermark detection pipeline (QRMark §5.1).

Stages: preprocess (load/transform) -> tiling -> decode (extractor) ->
RS correction.  Three pipeline modes:

* ``sequential``  — Stable-Signature-style baseline: unfused preprocess,
  full-image decode, synchronous CPU RS per batch.
* ``tiled``       — + tile-based decode (the naive-tiling midpoint the
  paper profiles at ~1.17x).
* ``qrmark``      — + fused preprocess kernel, adaptive lane allocation,
  LPT mini-batch scheduling, inter-batch interleaving, async RS
  (CPU thread pool w/ codebook, or fully on-device batched RS).

The pipeline object is the unit the benchmarks (Fig. 6/7/8) drive.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import allocator, interleave, losses, scheduler, tiling, \
    transforms
from repro.core.extractor import extractor_forward
from repro.core.rs.codec import DEFAULT_CODE, RSCode, rs_decode
from repro.core.rs import jax_rs
from repro.core.rs.cpu_pool import RSCodebook, RSCorrectionPool


@dataclasses.dataclass
class DetectionConfig:
    tile: int = 64
    img_size: int = 256
    resize_src: int = 288          # raw -> resize -> centercrop(img_size)
    strategy: str = "random_grid"
    code: RSCode = DEFAULT_CODE
    mode: str = "qrmark"           # sequential | tiled | qrmark
    rs_mode: str = "device"        # device | cpu_pool | cpu_sync
    fused_preprocess: bool = True
    interleave: bool = True
    rs_threads: int = 32
    lane_budget: int = 8
    seed: int = 0


class DetectionPipeline:
    """Drives (preprocess -> tile -> decode -> RS) over image streams."""

    def __init__(self, cfg: DetectionConfig, extractor_params,
                 ground_truth_bits: Optional[np.ndarray] = None):
        self.cfg = cfg
        self.params = extractor_params
        self.gt = ground_truth_bits
        self.code = cfg.code
        self._key = jax.random.key(cfg.seed)
        self._rs_pool: Optional[RSCorrectionPool] = None
        self._device_rs = None
        self._seq = 0
        self.stats: Dict[str, float] = {"batches": 0, "images": 0}
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg = self.cfg
        tile = cfg.tile if cfg.mode != "sequential" else cfg.img_size

        if cfg.fused_preprocess and cfg.mode == "qrmark":
            from repro.kernels import ops as kops
            self._preprocess = jax.jit(
                lambda raw: kops.fused_preprocess(
                    raw, resize=cfg.resize_src, crop=cfg.img_size))
        else:
            self._preprocess = jax.jit(
                lambda raw: transforms.preprocess_reference(
                    raw, resize=cfg.resize_src, crop=cfg.img_size))

        def decode_stage(images, key):
            if cfg.mode == "sequential":
                tiles = images  # full-image decode
            else:
                tiles, _ = tiling.select_tiles(cfg.strategy, key, images,
                                               cfg.tile)
            return extractor_forward(self.params, tiles)

        self._decode = jax.jit(decode_stage)

        if cfg.rs_mode == "device":
            self._device_rs = jax_rs.make_batch_decoder(self.code)
        elif cfg.rs_mode == "cpu_pool":
            self._rs_pool = RSCorrectionPool(self.code,
                                             n_threads=cfg.rs_threads)

        # fully fused fast path (qrmark + device RS): one jitted graph
        if cfg.mode == "qrmark" and cfg.rs_mode == "device":
            dev_decoder = jax_rs.make_decoder(self.code)

            def fused(raw, key):
                x = self._preprocess_fn_inline(raw)
                tiles, _ = tiling.select_tiles(cfg.strategy, key, x,
                                               cfg.tile)
                logits = extractor_forward(self.params, tiles)
                bits = (logits > 0).astype(jnp.int32)
                return jax.vmap(dev_decoder)(bits), logits

            self._fused = jax.jit(fused)
        else:
            self._fused = None

    def _preprocess_fn_inline(self, raw):
        cfg = self.cfg
        if cfg.fused_preprocess and cfg.mode == "qrmark":
            from repro.kernels import ops as kops
            return kops.fused_preprocess(raw, resize=cfg.resize_src,
                                         crop=cfg.img_size)
        return transforms.preprocess_reference(raw, resize=cfg.resize_src,
                                               crop=cfg.img_size)

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    # ------------------------------------------------------------------
    def detect_batch(self, raw_batch) -> Dict[str, np.ndarray]:
        """Synchronous detection of one raw uint8 image batch."""
        cfg = self.cfg
        b = raw_batch.shape[0]
        if self._fused is not None:
            (rs_out, logits) = self._fused(raw_batch, self._next_key())
            msg = np.asarray(rs_out["message_bits"])
            ok = np.asarray(rs_out["ok"])
            ncorr = np.asarray(rs_out["n_corrected"])
        else:
            x = self._preprocess(raw_batch)
            logits = self._decode(x, self._next_key())
            bits = np.asarray((logits > 0).astype(jnp.int32))
            msg = np.zeros((b, self.code.message_bits), np.int32)
            ok = np.zeros((b,), bool)
            ncorr = np.zeros((b,), np.int32)
            if cfg.rs_mode == "cpu_pool":
                base = self._seq
                self._seq += b
                self._rs_pool.submit_batch(bits, base)
                for i, (mi, oki) in enumerate(
                        self._rs_pool.drain(range(base, base + b))):
                    msg[i], ok[i] = mi[: self.code.message_bits], oki
            else:  # cpu_sync
                for i in range(b):
                    res = rs_decode(self.code, bits[i])
                    msg[i] = res.message_bits
                    ok[i] = res.ok
                    ncorr[i] = res.n_corrected
        self.stats["batches"] += 1
        self.stats["images"] += b
        out = {"message_bits": msg, "ok": ok, "n_corrected": ncorr,
               "logits": np.asarray(logits)}
        if self.gt is not None:
            out["match"] = np.all(
                msg == self.gt[None, : msg.shape[1]], axis=1)
        return out

    # ------------------------------------------------------------------
    def run_stream(self, batches, *, scheduled: bool = True) -> dict:
        """Detect a stream of batches; returns throughput metrics."""
        cfg = self.cfg
        it = interleave.interleaved(
            batches, prepare=None, enabled=(cfg.interleave
                                            and cfg.mode == "qrmark"))
        n_img = 0
        t0 = time.perf_counter()
        results = []
        for raw in it:
            results.append(self.detect_batch(raw))
            n_img += raw.shape[0]
        # drain async RS
        wall = time.perf_counter() - t0
        return {"images": n_img, "wall_s": wall,
                "throughput_ips": n_img / wall if wall > 0 else 0.0,
                "results": results}

    def close(self):
        if self._rs_pool is not None:
            self._rs_pool.close()


def verify_against_key(message_bits: np.ndarray, key_bits: np.ndarray,
                       fpr: float = 1e-6) -> np.ndarray:
    """Statistical verification: match if the bit agreement exceeds the
    threshold tau solving  P[Binomial(n, 0.5) >= tau] <= fpr."""
    n = key_bits.shape[-1]
    # Chernoff-style threshold (exact binomial tail via DP for small n)
    tail = np.zeros(n + 1)
    # P[X >= j] for X ~ Bin(n, 1/2)
    from math import comb
    probs = np.array([comb(n, i) for i in range(n + 1)], dtype=float)
    probs /= probs.sum()
    cum = np.cumsum(probs[::-1])[::-1]
    tau = int(np.argmax(cum <= fpr))
    agree = np.sum(message_bits == key_bits[None, :], axis=-1)
    return agree >= tau
