"""End-to-end watermark detection pipeline (QRMark §5.1) as an explicit
stage graph.

Stages: ingest (host->device + fused preprocess) -> tiled decode
(extractor) -> RS correction.  Three pipeline modes:

* ``sequential``  — Stable-Signature-style baseline: unfused preprocess,
  full-image decode, synchronous CPU RS per batch.
* ``tiled``       — + tile-based decode (the naive-tiling midpoint the
  paper profiles at ~1.17x).
* ``qrmark``      — + tile-first fused ingest, adaptive lane allocation,
  LPT mini-batch scheduling, inter-batch interleaving, async RS
  (CPU thread pool w/ codebook, or fully on-device batched RS).

Tile-first ingest (the qrmark default, ``cfg.tile_first``): per-image
tile offsets are derived from the fold_in keys *before* ingest — they
depend only on the key and the static image geometry — and handed to
``kernels.ops.fused_tile_preprocess``, which slices the interpolation
matrices down to the selected tile's rows/columns so ingest computes
exactly the (b, tile, tile, 3) decode input and never materialises the
full preprocessed image (~4-6x fewer ingest FLOPs at 256^2/64^2,
~16x less ingest output).  Decode is then just the extractor forward.
``tile_first=False`` keeps the staged full-image preprocess +
``select_tiles_per_image`` path; both are bit-identical by construction
(output row i of the interpolation matmul depends only on row i of Ry).

Decode (the qrmark default, ``cfg.fused_decode``) is the fused Pallas
extractor kernel (``kernels/fused_extractor.py``): the whole forward —
im2col-matmul conv blocks with fused norm/ReLU epilogues, GAP + head,
correlation bank — in one kernel launch per tile batch, on weights
packed once per pipeline build (``extractor.pack_params``).
``cfg.decode_dtype`` is the precision policy: "fp32" is bit-identical
to the unfused ``extractor_forward`` graph (they share one body);
"bf16" computes the matmuls at bf16 with fp32 accumulation — logit
perturbations ~1e-2, occasionally flipping a zero-margin bit, which RS
absorbs (one bit = one GF(16) symbol, within the t=1 radius); "int8"
is the lowest rung — per-channel weight scales baked in at pack time,
per-row activation quantization, int32 accumulation — whose slightly
larger perturbations RS absorbs the same way.  ``cfg.decode_schedule``
picks the kernel blocking ("flat", "auto" = the autotune cache at
``cfg.autotune_cache``, or an explicit "bb<N>-ct<N>[-db]" point); fp32
output is bitwise identical on every schedule, so the schedule is a
pure throughput knob (``kernels/autotune.py``).
Per-image fold_in keys are derived once per batch (offline) or once per
request (online) by ``StageRegistry.image_keys`` and flow to every
stage through the payload as explicit inputs.

Execution engines, all deriving their compute from ONE
:class:`repro.core.stages.StageRegistry` (the single definition of the
ingest/decode/RS stage functions, the fused fast path, and the RNG-key
discipline — nothing is restated here):

* :meth:`DetectionPipeline.detect_batch` — one batch, synchronous (plus
  a fully-fused single-jit fast path for qrmark + device RS);
* :meth:`DetectionPipeline.run_stream` — a stream of batches through the
  :class:`repro.core.lanes.LaneExecutor`: N lanes per stage (from the
  §6.2 allocator), bounded queues, multiple mini-batches in flight;
* :meth:`DetectionPipeline.run_batch` — data-parallel sharding of one
  (possibly ragged) batch across all local devices via a 1-D
  ``NamedSharding`` mesh;
* :class:`repro.serving.server.DetectionServer` — the online
  request-level runtime: the same stage graph on a persistent
  service-mode executor behind a dynamic micro-batcher.

Stage handoff is zero-copy: payloads stay device arrays between lanes
(bits are thresholded on device, ``rs_mode="device"`` feeds them
straight into the batched decoder — the Pallas Berlekamp-Welch kernel
for the default (15,12) GF(16) code, ``jax_rs`` otherwise) and nothing
is pulled to numpy before the sink (:meth:`_finish`).

RNG discipline: batch k uses ``fold_in(key(seed), k)`` and image i of a
batch uses ``fold_in(batch_key, i)``, so results are bit-identical
regardless of lane count, execution order, batch padding, or sharding.

Adaptive multi-tile escalation (``cfg.escalate_tiles > 1``, see
docs/detection.md): every engine runs the unchanged single-tile round
first, then re-decodes only RS failures (or thin-margin decodes,
``cfg.escalate_margin``) on up to k-1 additional non-colliding tiles
of the per-image plan, accumulating soft bits between RS attempts
(:meth:`repro.core.stages.StageRegistry.escalate`).  Results gain a
``tiles_used`` column; with ``escalate_tiles=1`` nothing changes, bit
for bit.

The pipeline object is the unit the benchmarks (Fig. 6-10, 12) drive.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Union

import jax
import numpy as np

from repro.core import interleave, lanes as lanes_lib
# make_device_rs / STAGE_NAMES moved to repro.core.stages; re-exported
# here for callers that import them from the pipeline module
from repro.core.stages import (STAGE_NAMES, StageRegistry,  # noqa: F401
                               make_device_rs)
from repro.core.rs.codec import DEFAULT_CODE, RSCode


@dataclasses.dataclass
class DetectionConfig:
    """Configuration shared by every detection engine.

    RNG/bit-identity contract: all randomness (tile choice, escalation
    plans) derives from ``seed`` via ``fold_in`` — batch k uses
    ``fold_in(key(seed), k)``, image i of a batch ``fold_in(batch_key,
    i)`` — so for a fixed config the same images produce bitwise equal
    results on every engine, lane count, padding, or sharding.

    Escalation knobs (see ``stages.EscalationPolicy`` and
    ``docs/detection.md``): ``escalate_tiles`` is the per-image tile
    budget — 1 (default) disables escalation and keeps every engine
    bit-identical to the single-tile pipeline; k > 1 re-decodes failed
    images on up to k-1 additional non-colliding tiles, accumulating
    soft bits between RS attempts.  ``escalate_margin`` > 0 also
    escalates images whose mean |logit| is below the margin even when
    RS formally succeeded.

    Cache knobs (consumed by the online ``serving.DetectionServer``;
    offline engines ignore them): ``cache_exact`` enables the tier-1
    content-hash (sha256) result cache plus dedup-in-flight — and
    switches keyless requests to *content-derived* keys
    (``fold_in(key(seed), fingerprint32(sha256 digest))``), so
    identical pixels produce identical keys and a cache hit is bitwise
    what the cold path would compute.  ``cache_embedding_threshold`` > 0 enables the
    tier-2 near-duplicate cache over the extractor's GAP embedding
    (approximate by design; it only short-circuits escalation
    rounds)."""
    tile: int = 64
    img_size: int = 256
    resize_src: int = 288          # raw -> resize -> centercrop(img_size)
    strategy: str = "random_grid"
    code: RSCode = DEFAULT_CODE
    mode: str = "qrmark"           # sequential | tiled | qrmark
    rs_mode: str = "device"        # device | cpu_pool | cpu_sync
    fused_preprocess: bool = True
    tile_first: bool = True        # fuse tile selection into ingest
    fused_decode: bool = True      # Pallas fused-extractor decode kernel
    decode_dtype: str = "fp32"     # fp32 (bit-exact) | bf16 | int8
    decode_schedule: str = "flat"  # flat | auto | "bb<N>-ct<N>[-db]"
    autotune_cache: str = ""       # schedule cache path for "auto"
    interleave: bool = True
    rs_threads: int = 32
    lane_budget: int = 8
    escalate_tiles: int = 1        # max tiles/image (1 = no escalation)
    escalate_margin: float = 0.0   # mean-|logit| floor (0 = RS-only)
    # -- online result cache (serving.cache; offline engines ignore) --
    cache_exact: bool = False      # tier-1 exact sha256 cache + dedup
    cache_embedding_threshold: float = 0.0  # tier-2 cosine floor (0=off)
    cache_capacity: int = 256      # tier-1 LRU entries (requests)
    cache_embedding_capacity: int = 512  # tier-2 LRU entries (images)
    seed: int = 0


class DetectionPipeline:
    """Drives (ingest -> tile+decode -> RS) over image streams.

    The pipeline is a thin engine layer: all stage compute, the fused
    fast path, the RS engines, and the key discipline live in its
    :class:`~repro.core.stages.StageRegistry` (``self.stages``), which
    the online :class:`~repro.serving.server.DetectionServer` shares."""

    def __init__(self, cfg: DetectionConfig, extractor_params,
                 ground_truth_bits: Optional[np.ndarray] = None):
        self.cfg = cfg
        self.params = extractor_params
        self.gt = ground_truth_bits
        self.code = cfg.code
        self.stages = StageRegistry(cfg, extractor_params)
        self.tile_first = self.stages.tile_first
        self.fused_decode = self.stages.fused_decode
        self.packed_params = self.stages.packed_params
        self._seq = 0                 # batch counter (keys)
        self._stats_lock = threading.Lock()  # _finish runs on rs lanes
        self.stats: Dict[str, float] = {"batches": 0, "images": 0}

    # ------------------------------------------------------------------
    def _batch_key(self, seq: int):
        return self.stages.batch_key(seq)

    # -- staged compute, shared by detect_batch and run_batch ----------
    def _ingest(self, raw, key):
        """raw uint8 batch -> (decode input, per-image keys): the
        selected tiles directly (tile-first) or the full preprocessed
        images (staged).  The per-image fold_in keys are derived here,
        once per batch, and handed to decode."""
        keys = self.stages.image_keys(key, raw.shape[0])
        return self.stages.ingest_keyed(raw, keys), keys

    def _decode_x(self, x, keys):
        """decode input + per-image keys -> bit logits (tile selection
        already folded into ingest on the tile-first path)."""
        return self.stages.decode_keyed(x, keys)

    def _bits(self, logits):
        return self.stages.bits(logits)

    def _rs_correct(self, bits):
        """(msg, ok, ncorr) via the registry's configured RS engine."""
        return self.stages.rs_correct(bits)

    def _finish(self, msg, ok, ncorr, logits, b,
                tiles_used=None) -> Dict[str, np.ndarray]:
        """The sink: the single place device arrays become numpy.
        ``tiles_used`` (escalation round counts) is reported only when
        escalation is configured, so ``escalate_tiles=1`` results keep
        the exact pre-escalation schema."""
        with self._stats_lock:
            self.stats["batches"] += 1
            self.stats["images"] += b
        out = {"message_bits": np.asarray(msg), "ok": np.asarray(ok),
               "n_corrected": np.asarray(ncorr),
               "logits": np.asarray(logits)}
        if tiles_used is not None and self.stages.policy.enabled:
            out["tiles_used"] = np.asarray(tiles_used)
        if self.gt is not None:
            out["match"] = np.all(
                out["message_bits"] == self.gt[None, : msg.shape[1]],
                axis=1)
        return out

    # ------------------------------------------------------------------
    def detect_batch(self, raw_batch, *, key=None,
                     true_b: Optional[int] = None
                     ) -> Dict[str, np.ndarray]:
        """Synchronous detection of one raw uint8 image batch.

        ``key`` defaults to the offline discipline
        (``fold_in(key(seed), batch_seq)``); per-image keys derive from
        it, so explicit keys make results independent of call order.
        With ``escalate_tiles > 1`` the adaptive escalation loop runs
        after the (unchanged) single-tile round; the result gains a
        ``tiles_used`` column and ``logits`` become the accumulated
        soft bits for escalated images.  Callers that padded the batch
        (bucket shaping) pass ``true_b`` so pad rows never escalate
        (they repeat the last real image and get sliced off anyway)."""
        b = raw_batch.shape[0]
        if key is None:
            key = self._batch_key(self._seq)
            self._seq += 1
        if self.stages.fused_keyed is not None:
            keys = self.stages.image_keys(key, b)
            (rs_out, logits) = self.stages.fused_keyed(raw_batch, keys)
            msg, ok, ncorr = (rs_out["message_bits"], rs_out["ok"],
                              rs_out["n_corrected"])
        else:
            x, keys = self._ingest(raw_batch, key)
            logits = self._decode_x(x, keys)
            msg, ok, ncorr = self._rs_correct(self._bits(logits))
        tiles_used = None
        if self.stages.policy.enabled:
            msg, ok, ncorr, logits, tiles_used = \
                self.stages.escalate_prefix(
                    raw_batch, keys, msg, ok, ncorr, logits, true_b)
        return self._finish(msg, ok, ncorr, logits, b, tiles_used)

    # -- stage graph ----------------------------------------------------
    def default_lanes(self) -> Dict[str, int]:
        """Static lane split within ``cfg.lane_budget`` (Algorithm 1's
        warm-start: the decode stage is the GPU-intensive one and gets
        the most lanes; use ``allocator.assign`` for the profiled
        allocation)."""
        cfg = self.cfg
        if cfg.mode != "qrmark":
            return {n: 1 for n in STAGE_NAMES}
        budget = max(3, cfg.lane_budget)
        decode = min(4, max(1, budget // 2))
        rs = min(4, max(1, budget - decode - 1))
        return {"ingest": 1, "decode": decode, "rs": rs}

    def _finish_payload(self, p: dict) -> Dict[str, np.ndarray]:
        """Registry stage-graph sink for the offline engines."""
        logits = p["logits"]
        return self._finish(p["msg"], p["ok"], p["ncorr"], logits,
                            logits.shape[0], p.get("tiles_used"))

    def build_stages(self, lanes: Optional[Dict[str, int]] = None
                     ) -> List[lanes_lib.Stage]:
        """The detection stage graph for the lane executor — the
        registry's single payload-stage definition with :meth:`_finish`
        as the sink (payloads carry pre-derived per-image ``keys``, so
        stage functions are pure and any lane count is bit-identical to
        serial; see :meth:`StageRegistry.build_stages`)."""
        ln = {**self.default_lanes(), **(lanes or {})}
        return self.stages.build_stages(
            ln, finish=self._finish_payload,
            depth=2 if self.cfg.interleave else 1)

    # ------------------------------------------------------------------
    def run_stream(self, batches: Iterable, *, scheduled: bool = True,
                   lanes: Union[None, int, Dict[str, int]] = None,
                   on_result: Optional[Callable[[int, dict], None]] = None
                   ) -> dict:
        """Detect a stream of batches; returns throughput metrics.

        RNG/bit-identity contract: batch i of the stream uses key
        ``fold_in(key(cfg.seed), seq0 + i)`` (the pipeline's running
        sequence counter), and per-image keys derive from it — so for
        ANY lane configuration the results equal serial
        :meth:`detect_batch` calls over the same stream, bitwise,
        escalation included.

        ``lanes``: None -> lane executor with :meth:`default_lanes` for
        qrmark (plain prefetch loop otherwise); int n -> n decode + n RS
        lanes; dict -> explicit per-stage lane counts.

        Stream items are raw batches, or ``(raw, true_b)`` tuples when
        the feeder padded them — pad rows then never escalate (the
        consumer is expected to slice results to ``true_b``).

        ``on_result(i, res)`` fires as result ``i`` is consumed from the
        executor — the hook latency monitors need (a completion recorded
        after the whole stream finished measures nothing)."""
        cfg = self.cfg
        use_exec = lanes is not None or cfg.mode == "qrmark"
        if isinstance(lanes, int):
            lanes = {"ingest": 1, "decode": max(1, lanes),
                     "rs": max(1, lanes)}
        n_img = 0
        results = []
        t0 = time.perf_counter()
        if use_exec:
            stages = self.build_stages(lanes)
            ex = lanes_lib.LaneExecutor(stages, name="detect")
            seq0 = self._seq

            def feed():
                for i, item in enumerate(batches):
                    raw, tb = (item if isinstance(item, tuple)
                               else (item, None))
                    bkey = self._batch_key(seq0 + i)
                    p = {"raw": raw, "seq": seq0 + i,
                         "keys": self.stages.image_keys(
                             bkey, raw.shape[0])}
                    if tb is not None:
                        p["true_b"] = tb
                    yield p

            for r in ex.run(feed()):
                if on_result is not None:
                    on_result(len(results), r)
                results.append(r)
                n_img += r["logits"].shape[0]
            self._seq = seq0 + len(results)
            lane_map = {s.name: s.lanes for s in stages}
        else:
            it = interleave.interleaved(
                batches, prepare=None,
                enabled=(cfg.interleave and cfg.mode == "qrmark"))
            for item in it:
                raw, tb = (item if isinstance(item, tuple)
                           else (item, None))
                r = self.detect_batch(raw, true_b=tb)
                if on_result is not None:
                    on_result(len(results), r)
                results.append(r)
                n_img += raw.shape[0]
            lane_map = {n: 1 for n in STAGE_NAMES}
        wall = time.perf_counter() - t0
        return {"images": n_img, "wall_s": wall,
                "throughput_ips": n_img / wall if wall > 0 else 0.0,
                "lanes": lane_map, "results": results}

    # ------------------------------------------------------------------
    def run_batch(self, raw_batch, *, mesh=None,
                  key=None) -> Dict[str, np.ndarray]:
        """One (possibly ragged) batch, data-parallel across devices.

        The batch is padded up to the mesh's data-axis size, sharded
        with a ``NamedSharding`` over the 1-D device mesh, pushed
        through the staged jitted functions (tile-first ingest when
        configured — tile extraction is per-image, so the sharded graph
        stays collective-free), and sliced back to the true batch size.
        Per-image RNG keys make the pad rows inert: every real image's
        result is bit-identical to the single-device staged path."""
        from repro.launch import mesh as mesh_lib
        from repro.sharding import planner

        if key is None:
            key = self._batch_key(self._seq)
            self._seq += 1
        b = raw_batch.shape[0]
        if mesh is None:
            mesh = mesh_lib.make_detection_mesh()
        ndev = mesh.devices.size
        pad = (-b) % ndev
        raw_np = np.asarray(raw_batch)
        if pad:
            raw_np = np.concatenate(
                [raw_np, np.repeat(raw_np[-1:], pad, axis=0)])
        x_in = planner.shard_detection_batch(mesh, raw_np)
        # per-image keys shard with the batch (fold_in is per-image, so
        # the sharded graph stays collective-free)
        keys = jax.device_put(
            self.stages.image_keys(key, raw_np.shape[0]),
            jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("data")))
        x = self.stages.ingest_keyed(x_in, keys)
        logits = self._decode_x(x, keys)
        bits = self._bits(logits)
        if self.cfg.rs_mode == "device":
            # decode the padded batch (shape-stable jit), slice after
            msg, ok, ncorr = (a[:b] for a in self._rs_correct(bits))
        else:
            msg, ok, ncorr = self._rs_correct(np.asarray(bits)[:b])
        logits_b = np.asarray(logits)[:b]
        tiles_used = None
        if self.stages.policy.enabled:
            # escalation runs unsharded on the true-size failing subset
            # (sub-batches are small); keys stay the padded batch's
            # per-image keys, so tile plans match the single-device path
            msg, ok, ncorr, logits_b, tiles_used = self.stages.escalate(
                raw_np[:b], keys[:b], msg, ok, ncorr, logits_b)
        return self._finish(msg, ok, ncorr, logits_b, b, tiles_used)

    def close(self):
        self.stages.close()


def verify_against_key(message_bits: np.ndarray, key_bits: np.ndarray,
                       fpr: float = 1e-6) -> np.ndarray:
    """Statistical verification: match if the bit agreement exceeds the
    threshold tau solving  P[Binomial(n, 0.5) >= tau] <= fpr."""
    n = key_bits.shape[-1]
    tau = binomial_threshold(n, fpr)
    agree = np.sum(message_bits == key_bits[None, :], axis=-1)
    return agree >= tau


def _binomial_threshold_uncached(n: int, fpr: float) -> int:
    """Smallest tau with  P[Binomial(n, 1/2) >= tau] <= fpr  (exact
    tail via the binomial coefficients).  When even full agreement
    cannot reach the target (2^-n > fpr), returns n + 1 so
    verification fails closed instead of accepting everything."""
    from math import comb
    probs = np.array([comb(n, i) for i in range(n + 1)], dtype=float)
    probs /= probs.sum()
    cum = np.cumsum(probs[::-1])[::-1]
    sat = np.nonzero(cum <= fpr)[0]
    return int(sat[0]) if sat.size else n + 1


@functools.lru_cache(maxsize=None)
def binomial_threshold(n: int, fpr: float) -> int:
    """Cached :func:`_binomial_threshold_uncached`: tau depends only on
    (n, fpr), but the exact tail rebuilds the full ``comb`` table —
    O(n) bignum work — on every call, which :func:`verify_against_key`
    sits on for every served verification batch.  The cache makes
    repeated thresholds a dict hit."""
    return _binomial_threshold_uncached(n, fpr)
