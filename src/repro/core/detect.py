"""End-to-end watermark detection pipeline (QRMark §5.1) as an explicit
stage graph.

Stages: ingest (host->device + fused preprocess) -> tiled decode
(extractor) -> RS correction.  Three pipeline modes:

* ``sequential``  — Stable-Signature-style baseline: unfused preprocess,
  full-image decode, synchronous CPU RS per batch.
* ``tiled``       — + tile-based decode (the naive-tiling midpoint the
  paper profiles at ~1.17x).
* ``qrmark``      — + tile-first fused ingest, adaptive lane allocation,
  LPT mini-batch scheduling, inter-batch interleaving, async RS
  (CPU thread pool w/ codebook, or fully on-device batched RS).

Tile-first ingest (the qrmark default, ``cfg.tile_first``): per-image
tile offsets are derived from the fold_in keys *before* ingest — they
depend only on the key and the static image geometry — and handed to
``kernels.ops.fused_tile_preprocess``, which slices the interpolation
matrices down to the selected tile's rows/columns so ingest computes
exactly the (b, tile, tile, 3) decode input and never materialises the
full preprocessed image (~4-6x fewer ingest FLOPs at 256^2/64^2,
~16x less ingest output).  Decode is then just the extractor forward.
``tile_first=False`` keeps the staged full-image preprocess +
``select_tiles_per_image`` path; both are bit-identical by construction
(output row i of the interpolation matmul depends only on row i of Ry).

Decode (the qrmark default, ``cfg.fused_decode``) is the fused Pallas
extractor kernel (``kernels/fused_extractor.py``): the whole forward —
im2col-matmul conv blocks with fused norm/ReLU epilogues, GAP + head,
correlation bank — in one kernel launch per tile batch, on weights
packed once per pipeline build (``extractor.pack_params``).
``cfg.decode_dtype`` is the precision policy: "fp32" is bit-identical
to the unfused ``extractor_forward`` graph (they share one body);
"bf16" computes the matmuls at bf16 with fp32 accumulation — logit
perturbations ~1e-2, occasionally flipping a zero-margin bit, which RS
absorbs (one bit = one GF(16) symbol, within the t=1 radius).
Per-image fold_in keys are derived once per batch, in ingest, and flow
to decode through the stage payload.

Execution engines, all driving the same jitted stage functions:

* :meth:`DetectionPipeline.detect_batch` — one batch, synchronous (plus
  a fully-fused single-jit fast path for qrmark + device RS);
* :meth:`DetectionPipeline.run_stream` — a stream of batches through the
  :class:`repro.core.lanes.LaneExecutor`: N lanes per stage (from the
  §6.2 allocator), bounded queues, multiple mini-batches in flight;
* :meth:`DetectionPipeline.run_batch` — data-parallel sharding of one
  (possibly ragged) batch across all local devices via a 1-D
  ``NamedSharding`` mesh.

Stage handoff is zero-copy: payloads stay device arrays between lanes
(bits are thresholded on device, ``rs_mode="device"`` feeds them
straight into the batched decoder — the Pallas Berlekamp-Welch kernel
for the default (15,12) GF(16) code, ``jax_rs`` otherwise) and nothing
is pulled to numpy before the sink (:meth:`_finish`).

RNG discipline: batch k uses ``fold_in(key(seed), k)`` and image i of a
batch uses ``fold_in(batch_key, i)``, so results are bit-identical
regardless of lane count, execution order, batch padding, or sharding.

The pipeline object is the unit the benchmarks (Fig. 6/7/8/9) drive.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extractor as extractor_lib
from repro.core import interleave, lanes as lanes_lib, tiling, transforms
from repro.core.extractor import extractor_forward
from repro.core.rs.codec import DEFAULT_CODE, RSCode, rs_decode
from repro.core.rs import jax_rs
from repro.core.rs.cpu_pool import RSCorrectionPool

STAGE_NAMES = ("ingest", "decode", "rs")

# the code the Pallas Berlekamp-Welch kernel is specialised for
_PALLAS_RS_CODE = (4, 15, 12)  # (m, n, k)


@dataclasses.dataclass
class DetectionConfig:
    tile: int = 64
    img_size: int = 256
    resize_src: int = 288          # raw -> resize -> centercrop(img_size)
    strategy: str = "random_grid"
    code: RSCode = DEFAULT_CODE
    mode: str = "qrmark"           # sequential | tiled | qrmark
    rs_mode: str = "device"        # device | cpu_pool | cpu_sync
    fused_preprocess: bool = True
    tile_first: bool = True        # fuse tile selection into ingest
    fused_decode: bool = True      # Pallas fused-extractor decode kernel
    decode_dtype: str = "fp32"     # fp32 (bit-exact) | bf16 (MXU compute)
    interleave: bool = True
    rs_threads: int = 32
    lane_budget: int = 8
    seed: int = 0


def make_device_rs(code: RSCode) -> Callable:
    """The on-device batched RS engine: the Pallas Berlekamp-Welch
    kernel for the code it is specialised for, ``jax_rs`` otherwise.
    Jit-able and safe to inline into a larger jitted graph — every
    engine (fused fast path, lane executor, sharded run_batch) must use
    the same decoder so failure tie-breaking never diverges."""
    if (code.m, code.n, code.k) == _PALLAS_RS_CODE:
        from repro.kernels import ops as kops

        def decode(bits):
            return kops.rs_decode(bits, code=code)

        # jitted so sharded inputs (run_batch) go through the SPMD
        # partitioner instead of eager multi-device dispatch
        return jax.jit(decode)
    return jax_rs.make_batch_decoder(code)


class DetectionPipeline:
    """Drives (ingest -> tile+decode -> RS) over image streams."""

    def __init__(self, cfg: DetectionConfig, extractor_params,
                 ground_truth_bits: Optional[np.ndarray] = None):
        self.cfg = cfg
        self.params = extractor_params
        self.gt = ground_truth_bits
        self.code = cfg.code
        self._base_key = jax.random.key(cfg.seed)
        self._rs_pool: Optional[RSCorrectionPool] = None
        self._device_rs = None
        self._seq = 0                 # batch counter (keys)
        self._pool_seq = 0            # RS-pool job id counter
        self._pool_lock = threading.Lock()
        self._stats_lock = threading.Lock()  # _finish runs on rs lanes
        self.stats: Dict[str, float] = {"batches": 0, "images": 0}
        self._build()

    # ------------------------------------------------------------------
    def _batch_key(self, seq: int):
        return jax.random.fold_in(self._base_key, seq)

    @staticmethod
    def _image_keys(batch_key, b: int):
        return jax.vmap(lambda i: jax.random.fold_in(batch_key, i))(
            jnp.arange(b))

    def _build(self):
        cfg = self.cfg
        if cfg.mode not in ("sequential", "tiled", "qrmark"):
            raise ValueError(f"unknown pipeline mode {cfg.mode!r}")
        if cfg.rs_mode not in ("device", "cpu_pool", "cpu_sync"):
            raise ValueError(f"unknown rs_mode {cfg.rs_mode!r}")
        if cfg.decode_dtype not in extractor_lib.DECODE_DTYPES:
            raise ValueError(f"unknown decode_dtype {cfg.decode_dtype!r}")
        self.tile_first = (cfg.tile_first and cfg.mode == "qrmark"
                           and cfg.fused_preprocess)
        self.fused_decode = cfg.fused_decode and cfg.mode == "qrmark"

        # decode-stage extractor, one fn for every engine: the fused
        # Pallas kernel on pre-packed params (qrmark; pack once per
        # pipeline build, dtype = the precision policy) or the unfused
        # extractor_forward graph (bit-identical to the fp32 kernel —
        # they share extractor_forward_packed)
        if self.fused_decode:
            from repro.kernels import ops as kops
            self.packed_params = extractor_lib.pack_params(
                self.params, cfg.decode_dtype)

            def extract(tiles):
                return kops.fused_extractor(tiles, self.packed_params)
        else:
            self.packed_params = None

            def extract(tiles):
                return extractor_forward(self.params, tiles)

        def preprocess(raw):
            if cfg.fused_preprocess and cfg.mode == "qrmark":
                from repro.kernels import ops as kops
                return kops.fused_preprocess(raw, resize=cfg.resize_src,
                                             crop=cfg.img_size)
            return transforms.preprocess_reference(
                raw, resize=cfg.resize_src, crop=cfg.img_size)

        # ingest derives the per-image fold_in keys for the whole batch
        # — the single place they are computed; decode receives them
        # through the payload instead of re-deriving (the fold_in vmap
        # used to live in both the ingest and decode graphs on the
        # staged path).  Tile-first: offsets from the keys (static
        # geometry only), then one kernel straight to the decode input.
        def ingest(raw, batch_key):
            keys = self._image_keys(batch_key, raw.shape[0])
            if self.tile_first:
                from repro.kernels import ops as kops
                offs = tiling.tile_first_offsets(
                    cfg.strategy, keys, img_size=cfg.img_size,
                    tile=cfg.tile)
                x = kops.fused_tile_preprocess(
                    raw, offs, resize=cfg.resize_src, crop=cfg.img_size,
                    tile=cfg.tile)
            else:
                x = preprocess(raw)
            return x, keys

        self._ingest_jit = jax.jit(ingest)

        def decode_stage(x, keys):
            if self.tile_first or cfg.mode == "sequential":
                tiles = x  # tiles from ingest / full-image decode
            else:
                tiles, _ = tiling.select_tiles_per_image(
                    cfg.strategy, keys, x, cfg.tile)
            return extract(tiles)

        self._decode_jit = jax.jit(decode_stage)
        self._extract = jax.jit(extract)
        self._bits = jax.jit(
            lambda logits: (logits > 0).astype(jnp.int32))

        if cfg.rs_mode == "device":
            self._device_rs = make_device_rs(self.code)
        elif cfg.rs_mode == "cpu_pool":
            self._rs_pool = RSCorrectionPool(self.code,
                                             n_threads=cfg.rs_threads)

        # fully fused fast path (qrmark + device RS): one jitted graph.
        # The raw-batch buffer is donated — ingest is its only reader,
        # so the runtime can recycle the largest in-flight buffer while
        # decode/RS still run.  CPU cannot reuse a donated uint8 input
        # (it would only warn once per compile), so donation is applied
        # on accelerator backends only.
        if cfg.mode == "qrmark" and cfg.rs_mode == "device":
            dev_decoder = self._device_rs  # one decoder for every engine

            def fused(raw, batch_key):
                x, keys = ingest(raw, batch_key)
                logits = decode_stage(x, keys)
                bits = (logits > 0).astype(jnp.int32)
                return dev_decoder(bits), logits

            donate = () if jax.default_backend() == "cpu" else (0,)
            self._fused = jax.jit(fused, donate_argnums=donate)
        else:
            self._fused = None

    # -- staged compute, shared by detect_batch and run_batch ----------
    def _ingest(self, raw, key):
        """raw uint8 batch -> (decode input, per-image keys): the
        selected tiles directly (tile-first) or the full preprocessed
        images (staged).  The per-image fold_in keys are derived here,
        once per batch, and handed to decode."""
        return self._ingest_jit(raw, key)

    def _decode_x(self, x, keys):
        """decode input + per-image keys -> bit logits (tile selection
        already folded into ingest on the tile-first path)."""
        if self.tile_first:
            return self._extract(x)
        return self._decode_jit(x, keys)

    # -- RS correction, host-side engines ------------------------------
    def _rs_host(self, bits: np.ndarray):
        """(msg, ok, ncorr) via the configured host RS engine."""
        cfg = self.cfg
        b = bits.shape[0]
        msg = np.zeros((b, self.code.message_bits), np.int32)
        ok = np.zeros((b,), bool)
        ncorr = np.zeros((b,), np.int32)
        if cfg.rs_mode == "cpu_pool":
            with self._pool_lock:
                base = self._pool_seq
                self._pool_seq += b
            self._rs_pool.submit_batch(bits, base)
            for i, (mi, oki) in enumerate(
                    self._rs_pool.drain(range(base, base + b))):
                msg[i], ok[i] = mi[: self.code.message_bits], oki
        else:  # cpu_sync
            for i in range(b):
                res = rs_decode(self.code, bits[i])
                msg[i] = res.message_bits
                ok[i] = res.ok
                ncorr[i] = res.n_corrected
        return msg, ok, ncorr

    def _rs_correct(self, bits):
        """(msg, ok, ncorr) via the configured RS engine.  ``bits`` stays
        a device array end-to-end on the device path (zero-copy handoff);
        host engines pull it to numpy here, at their host boundary."""
        if self.cfg.rs_mode == "device":
            rs_out = self._device_rs(bits if isinstance(bits, jax.Array)
                                     else jnp.asarray(bits))
            return (rs_out["message_bits"], rs_out["ok"],
                    rs_out["n_corrected"])
        return self._rs_host(np.asarray(bits))

    def _finish(self, msg, ok, ncorr, logits, b) -> Dict[str, np.ndarray]:
        """The sink: the single place device arrays become numpy."""
        with self._stats_lock:
            self.stats["batches"] += 1
            self.stats["images"] += b
        out = {"message_bits": np.asarray(msg), "ok": np.asarray(ok),
               "n_corrected": np.asarray(ncorr),
               "logits": np.asarray(logits)}
        if self.gt is not None:
            out["match"] = np.all(
                out["message_bits"] == self.gt[None, : msg.shape[1]],
                axis=1)
        return out

    # ------------------------------------------------------------------
    def detect_batch(self, raw_batch, *, key=None) -> Dict[str, np.ndarray]:
        """Synchronous detection of one raw uint8 image batch."""
        cfg = self.cfg
        b = raw_batch.shape[0]
        if key is None:
            key = self._batch_key(self._seq)
            self._seq += 1
        if self._fused is not None:
            (rs_out, logits) = self._fused(raw_batch, key)
            msg, ok, ncorr = (rs_out["message_bits"], rs_out["ok"],
                              rs_out["n_corrected"])
        else:
            x, keys = self._ingest(raw_batch, key)
            logits = self._decode_x(x, keys)
            msg, ok, ncorr = self._rs_correct(self._bits(logits))
        return self._finish(msg, ok, ncorr, logits, b)

    # -- stage graph ----------------------------------------------------
    def default_lanes(self) -> Dict[str, int]:
        """Static lane split within ``cfg.lane_budget`` (Algorithm 1's
        warm-start: the decode stage is the GPU-intensive one and gets
        the most lanes; use ``allocator.assign`` for the profiled
        allocation)."""
        cfg = self.cfg
        if cfg.mode != "qrmark":
            return {n: 1 for n in STAGE_NAMES}
        budget = max(3, cfg.lane_budget)
        decode = min(4, max(1, budget // 2))
        rs = min(4, max(1, budget - decode - 1))
        return {"ingest": 1, "decode": decode, "rs": rs}

    def build_stages(self, lanes: Optional[Dict[str, int]] = None
                     ) -> List[lanes_lib.Stage]:
        """The detection stage graph for the lane executor.

        Payloads are dicts carrying ``raw`` -> ``x`` -> ``logits`` ->
        result; ``key`` is pre-derived by the feeder so stage functions
        are pure and any lane count is bit-identical to serial.  Between
        lanes everything stays a device array (jitted stage fns return
        futures; numpy conversion happens only in the :meth:`_finish`
        sink)."""
        cfg = self.cfg
        ln = {**self.default_lanes(), **(lanes or {})}
        depth = 2 if cfg.interleave else 1

        def st_ingest(p):
            p["x"], p["keys"] = self._ingest(
                jax.device_put(p["raw"]), p["key"])
            return p

        def st_decode(p):
            p["logits"] = self._decode_x(p["x"], p["keys"])
            return p

        def st_rs(p):
            logits = p["logits"]
            msg, ok, ncorr = self._rs_correct(self._bits(logits))
            return self._finish(msg, ok, ncorr, logits, logits.shape[0])

        return [
            lanes_lib.Stage("ingest", st_ingest, lanes=ln["ingest"],
                            depth=depth),
            lanes_lib.Stage("decode", st_decode, lanes=ln["decode"],
                            depth=depth, gpu_intensive=True),
            lanes_lib.Stage("rs", st_rs, lanes=ln["rs"], depth=depth),
        ]

    # ------------------------------------------------------------------
    def run_stream(self, batches: Iterable, *, scheduled: bool = True,
                   lanes: Union[None, int, Dict[str, int]] = None) -> dict:
        """Detect a stream of batches; returns throughput metrics.

        ``lanes``: None -> lane executor with :meth:`default_lanes` for
        qrmark (plain prefetch loop otherwise); int n -> n decode + n RS
        lanes; dict -> explicit per-stage lane counts."""
        cfg = self.cfg
        use_exec = lanes is not None or cfg.mode == "qrmark"
        if isinstance(lanes, int):
            lanes = {"ingest": 1, "decode": max(1, lanes),
                     "rs": max(1, lanes)}
        n_img = 0
        results = []
        t0 = time.perf_counter()
        if use_exec:
            stages = self.build_stages(lanes)
            ex = lanes_lib.LaneExecutor(stages, name="detect")
            seq0 = self._seq

            def feed():
                for i, raw in enumerate(batches):
                    yield {"raw": raw, "key": self._batch_key(seq0 + i),
                           "seq": seq0 + i}

            for r in ex.run(feed()):
                results.append(r)
                n_img += r["logits"].shape[0]
            self._seq = seq0 + len(results)
            lane_map = {s.name: s.lanes for s in stages}
        else:
            it = interleave.interleaved(
                batches, prepare=None,
                enabled=(cfg.interleave and cfg.mode == "qrmark"))
            for raw in it:
                results.append(self.detect_batch(raw))
                n_img += raw.shape[0]
            lane_map = {n: 1 for n in STAGE_NAMES}
        wall = time.perf_counter() - t0
        return {"images": n_img, "wall_s": wall,
                "throughput_ips": n_img / wall if wall > 0 else 0.0,
                "lanes": lane_map, "results": results}

    # ------------------------------------------------------------------
    def run_batch(self, raw_batch, *, mesh=None,
                  key=None) -> Dict[str, np.ndarray]:
        """One (possibly ragged) batch, data-parallel across devices.

        The batch is padded up to the mesh's data-axis size, sharded
        with a ``NamedSharding`` over the 1-D device mesh, pushed
        through the staged jitted functions (tile-first ingest when
        configured — tile extraction is per-image, so the sharded graph
        stays collective-free), and sliced back to the true batch size.
        Per-image RNG keys make the pad rows inert: every real image's
        result is bit-identical to the single-device staged path."""
        from repro.launch import mesh as mesh_lib
        from repro.sharding import planner

        if key is None:
            key = self._batch_key(self._seq)
            self._seq += 1
        b = raw_batch.shape[0]
        if mesh is None:
            mesh = mesh_lib.make_detection_mesh()
        ndev = mesh.devices.size
        pad = (-b) % ndev
        raw_np = np.asarray(raw_batch)
        if pad:
            raw_np = np.concatenate(
                [raw_np, np.repeat(raw_np[-1:], pad, axis=0)])
        x_in = planner.shard_detection_batch(mesh, raw_np)
        x, keys = self._ingest(x_in, key)
        logits = self._decode_x(x, keys)
        bits = self._bits(logits)
        if self.cfg.rs_mode == "device":
            # decode the padded batch (shape-stable jit), slice after
            msg, ok, ncorr = (a[:b] for a in self._rs_correct(bits))
        else:
            msg, ok, ncorr = self._rs_correct(np.asarray(bits)[:b])
        return self._finish(msg, ok, ncorr, np.asarray(logits)[:b], b)

    def close(self):
        if self._rs_pool is not None:
            self._rs_pool.close()


def verify_against_key(message_bits: np.ndarray, key_bits: np.ndarray,
                       fpr: float = 1e-6) -> np.ndarray:
    """Statistical verification: match if the bit agreement exceeds the
    threshold tau solving  P[Binomial(n, 0.5) >= tau] <= fpr."""
    n = key_bits.shape[-1]
    tau = binomial_threshold(n, fpr)
    agree = np.sum(message_bits == key_bits[None, :], axis=-1)
    return agree >= tau


def binomial_threshold(n: int, fpr: float) -> int:
    """Smallest tau with  P[Binomial(n, 1/2) >= tau] <= fpr  (exact
    tail via the binomial coefficients).  When even full agreement
    cannot reach the target (2^-n > fpr), returns n + 1 so
    verification fails closed instead of accepting everything."""
    from math import comb
    probs = np.array([comb(n, i) for i in range(n + 1)], dtype=float)
    probs /= probs.sum()
    cum = np.cumsum(probs[::-1])[::-1]
    sat = np.nonzero(cum <= fpr)[0]
    return int(sat[0]) if sat.size else n + 1
