"""QRMark training losses (§4.1).

L = L_m + lambda * L_RS, where L_m is the standard BCE message loss and
L_RS = [max(0, E - t)]^2 penalises only bit errors beyond the
Reed-Solomon correction capacity (errors the code can fix are free).
E is counted over the first k symbols' bits with a straight-through
surrogate so the hinge is differentiable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def message_loss(logits, messages):
    """BCE with logits.  logits/messages: (b, n_bits)."""
    m = messages.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * m
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def rs_aware_loss(logits, messages, *, t_symbols: float, symbol_bits: int,
                  k_symbols: int = None, temp: float = 1.0):
    """[max(0, E - t)]^2 with a soft SYMBOL error count (paper §4.1).

    e_i = 1[sign(m'_i) != m_i] per bit; a symbol is wrong if any of its m
    bits is wrong: soft_sym_err = 1 - prod_bits (1 - p_bit_err).  E sums
    over the first k symbols (the information part); errors within the RS
    capacity t incur no cost, beyond-capacity errors are squared.
    """
    m_pm = 2.0 * messages.astype(jnp.float32) - 1.0
    margin = logits * m_pm  # >0 means correct
    p_err = jax.nn.sigmoid(-margin / temp)  # (b, n_bits)
    b = p_err.shape[0]
    sym = p_err.reshape(b, -1, symbol_bits)
    if k_symbols is not None:
        sym = sym[:, :k_symbols]
    sym_err = 1.0 - jnp.prod(1.0 - sym, axis=-1)  # (b, n_sym)
    E = sym_err.sum(axis=-1)
    return jnp.mean(jnp.square(jnp.maximum(0.0, E - t_symbols)))


def qrmark_loss(logits, messages, *, code, lam: float = 1.0):
    lm = message_loss(logits, messages)
    lrs = rs_aware_loss(logits, messages, t_symbols=float(code.t),
                        symbol_bits=code.m, k_symbols=code.k)
    return lm + lam * lrs, {"L_m": lm, "L_RS": lrs}


def bit_accuracy(logits, messages):
    pred = (logits > 0).astype(jnp.int32)
    return jnp.mean((pred == messages.astype(jnp.int32)).astype(
        jnp.float32))


def word_accuracy(bits_pred, messages):
    eq = jnp.all(bits_pred.astype(jnp.int32)
                 == messages.astype(jnp.int32), axis=-1)
    return jnp.mean(eq.astype(jnp.float32))
