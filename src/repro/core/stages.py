"""Unified stage registry — the single definition of the detection
stage functions (QRMark §5.1/§6.2).

Every execution engine derives its compute from one
:class:`StageRegistry` built once per (config, params):

* ``DetectionPipeline.detect_batch`` — the keyed staged fns, or the
  fully fused single-jit fast path (``fused_keyed``);
* ``DetectionPipeline.build_stages`` / ``run_stream`` — the payload
  stage graph (:meth:`StageRegistry.build_stages`) for the lane
  executor;
* ``DetectionPipeline.run_batch`` — the same keyed staged fns over a
  sharded batch;
* ``serving.DetectionServer`` — the same payload stage graph, driven by
  a long-lived service-mode executor.

Before this module the ingest/decode/RS bodies were restated in four
places inside ``core/detect.py``; now they exist exactly once.

RNG-key discipline (the bit-identity contract): offline, batch k uses
``fold_in(key(seed), k)`` and image i of that batch uses
``fold_in(batch_key, i)``.  Key *derivation* is its own jitted function
(:meth:`image_keys`) and every stage function takes the derived
per-image key array as an explicit input — ``fold_in`` is integer
hashing, bit-exact wherever it runs, so a caller that supplies keys
from somewhere else (the online server derives them per *request*, not
per coalesced batch) gets results bit-identical to the offline engines
on the same images with the same keys, no matter how requests were
batched together.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extractor as extractor_lib
from repro.core import lanes as lanes_lib, tiling, transforms
from repro.core.extractor import extractor_forward
from repro.core.rs.codec import RSCode, rs_decode
from repro.core.rs import jax_rs
from repro.core.rs.cpu_pool import RSCorrectionPool

STAGE_NAMES = ("ingest", "decode", "rs")

# the code the Pallas Berlekamp-Welch kernel is specialised for
_PALLAS_RS_CODE = (4, 15, 12)  # (m, n, k)


def make_device_rs(code: RSCode) -> Callable:
    """The on-device batched RS engine: the Pallas Berlekamp-Welch
    kernel for the code it is specialised for, ``jax_rs`` otherwise.
    Jit-able and safe to inline into a larger jitted graph — every
    engine (fused fast path, lane executor, sharded run_batch, online
    server) must use the same decoder so failure tie-breaking never
    diverges."""
    if (code.m, code.n, code.k) == _PALLAS_RS_CODE:
        from repro.kernels import ops as kops

        def decode(bits):
            return kops.rs_decode(bits, code=code)

        # jitted so sharded inputs (run_batch) go through the SPMD
        # partitioner instead of eager multi-device dispatch
        return jax.jit(decode)
    return jax_rs.make_batch_decoder(code)


def _pad_pow2(arr, axis: int = 0):
    """Pad ``arr`` along ``axis`` up to the next power of two by
    repeating the last row; returns (padded, true_n).  Escalation
    sub-batches shrink round over round — pow2 buckets bound the number
    of jit shapes no matter how many images fail each round."""
    n = arr.shape[axis]
    target = 1
    while target < n:
        target *= 2
    if target == n:
        return arr, n
    reps = [arr] + [arr[n - 1: n]] * (target - n)
    if isinstance(arr, np.ndarray):
        return np.concatenate(reps, axis=axis), n
    return jnp.concatenate(reps, axis=axis), n


@dataclasses.dataclass(frozen=True)
class EscalationPolicy:
    """When and how far to escalate beyond the single-tile fast path
    (``DetectionConfig.escalate_tiles`` / ``escalate_margin``).

    ``max_tiles`` is the per-image tile budget (= max escalation
    rounds: round r decodes tile r of the per-image plan, so an image
    uses between 1 and ``max_tiles`` tiles).  An image escalates after
    a round when RS failed on its accumulated soft bits, or — with
    ``margin > 0`` — when the mean absolute accumulated logit is below
    ``margin`` (a thin verification margin, even if RS formally
    succeeded).  ``max_tiles == 1`` disables escalation entirely: no
    plan is derived and every engine's hot path is bit-identical to a
    pipeline built before this policy existed."""
    max_tiles: int = 1
    margin: float = 0.0

    @property
    def enabled(self) -> bool:
        return self.max_tiles > 1

    def wants_escalation(self, ok, logits) -> np.ndarray:
        """Per-image bool mask over (ok, accumulated logits)."""
        need = ~np.asarray(ok, bool)
        if self.margin > 0.0:
            need = need | (np.abs(np.asarray(logits)).mean(axis=-1)
                           < self.margin)
        return need


class StageRegistry:
    """The detection stage functions, built once per (cfg, params).

    Holds the jitted keyed stage fns, the packed decode weights, the
    configured RS engine (including the CPU pool's state), and the
    fused fast path.  Engine objects (pipeline, server) own a registry
    and derive everything from it."""

    def __init__(self, cfg, params):
        if cfg.mode not in ("sequential", "tiled", "qrmark"):
            raise ValueError(f"unknown pipeline mode {cfg.mode!r}")
        if cfg.rs_mode not in ("device", "cpu_pool", "cpu_sync"):
            raise ValueError(f"unknown rs_mode {cfg.rs_mode!r}")
        if cfg.decode_dtype not in extractor_lib.DECODE_DTYPES:
            raise ValueError(f"unknown decode_dtype {cfg.decode_dtype!r}")
        k = getattr(cfg, "escalate_tiles", 1)
        if k < 1:
            raise ValueError(f"escalate_tiles must be >= 1, got {k}")
        if getattr(cfg, "escalate_margin", 0.0) > 0.0 and k == 1:
            raise ValueError(
                "escalate_margin > 0 has no effect with "
                "escalate_tiles=1 — the margin trigger only fires "
                "when there is a tile budget to escalate into; set "
                "escalate_tiles > 1 (or margin to 0)")
        thr = getattr(cfg, "cache_embedding_threshold", 0.0)
        if not 0.0 <= thr <= 1.0:
            raise ValueError(
                f"cache_embedding_threshold must be in [0, 1] (cosine "
                f"floor; 0 disables the tier), got {thr}")
        if getattr(cfg, "cache_capacity", 1) < 1 or \
                getattr(cfg, "cache_embedding_capacity", 1) < 1:
            raise ValueError("cache capacities must be >= 1")
        if k > 1:
            if cfg.mode == "sequential":
                raise ValueError(
                    "escalate_tiles > 1 needs a tile-decoding mode "
                    "(tiled/qrmark); sequential decodes the full image")
            cap = tiling.max_escalation_tiles(
                cfg.strategy, (cfg.img_size, cfg.img_size), cfg.tile)
            if k > cap:
                raise ValueError(
                    f"escalate_tiles={k} exceeds the {cap} distinct "
                    f"{cfg.strategy!r} tiles of a {cfg.img_size}^2/"
                    f"{cfg.tile}^2 image")
        self.policy = EscalationPolicy(
            max_tiles=k, margin=getattr(cfg, "escalate_margin", 0.0))
        self.cfg = cfg
        self.params = params
        self.code = cfg.code
        self.base_key = jax.random.key(cfg.seed)
        self.tile_first = (cfg.tile_first and cfg.mode == "qrmark"
                           and cfg.fused_preprocess)
        self.fused_decode = cfg.fused_decode and cfg.mode == "qrmark"
        self._rs_pool: Optional[RSCorrectionPool] = None
        self._device_rs = None
        self._pool_seq = 0            # RS-pool job id counter
        self._pool_lock = threading.Lock()
        self._build()

    # -- RNG-key discipline --------------------------------------------
    def batch_key(self, seq: int):
        """Offline key for batch ``seq``: fold_in(key(cfg.seed), seq)."""
        return jax.random.fold_in(self.base_key, seq)

    def image_keys(self, key, b: int):
        """Per-image keys fold_in(key, 0..b-1) — THE derivation every
        engine shares (jitted per b; fold_in is bit-exact regardless of
        the enclosing graph, so deriving here vs inline is identical)."""
        return self._image_keys_jit(key, b)

    def content_key(self, fingerprint: int):
        """Content-addressed request key:
        ``fold_in(key(cfg.seed), fingerprint32(content digest))``.
        The serving tier uses this for keyless requests when the exact
        result cache is on — identical pixels then deterministically
        produce identical per-image keys, which is what makes a cache
        hit bitwise equal to the cold path (``fold_in`` is integer
        hashing, so this is the same contract as :meth:`batch_key`
        with content taking the place of arrival order)."""
        return jax.random.fold_in(self.base_key,
                                  np.uint32(fingerprint & 0xFFFFFFFF))

    # -- build ----------------------------------------------------------
    def _build(self):
        cfg = self.cfg

        # decode-stage extractor, one fn for every engine: the fused
        # Pallas kernel on pre-packed params (qrmark; pack once per
        # registry build, dtype = the precision policy) or the unfused
        # extractor_forward graph (bit-identical to the fp32 kernel —
        # they share extractor_forward_packed)
        if self.fused_decode:
            from repro.kernels import autotune as autotune_lib
            from repro.kernels import ops as kops
            self.packed_params = extractor_lib.pack_params(
                self.params, cfg.decode_dtype)
            # kernel schedule, resolved once per registry build: "flat"
            # -> None (the flat kernel), "auto" -> the autotune cache
            # (flat fallback with a printed hint on a miss), or an
            # explicit "bb<N>-ct<N>[-db]" point.  fp32 output is bitwise
            # schedule-independent, so this is purely a throughput knob.
            self.decode_schedule = autotune_lib.resolve_schedule(
                getattr(cfg, "decode_schedule", "flat"),
                dtype=cfg.decode_dtype, tile=cfg.tile,
                channels=self.params["blocks"][0]["w"].shape[-1],
                depth=len(self.params["blocks"]),
                n_bits=self.params["head"]["b"].shape[0],
                cache_path=getattr(cfg, "autotune_cache", ""))
            sched = self.decode_schedule

            def extract(tiles):
                return kops.fused_extractor(tiles, self.packed_params,
                                            schedule=sched)

            def extract_embed(tiles):
                return kops.fused_extractor(tiles, self.packed_params,
                                            schedule=sched,
                                            with_embed=True)
        else:
            self.packed_params = None
            self.decode_schedule = None

            def extract(tiles):
                return extractor_forward(self.params, tiles)

            def extract_embed(tiles):
                return extractor_lib.extractor_forward_embed(
                    self.params, tiles)

        def preprocess(raw):
            if cfg.fused_preprocess and cfg.mode == "qrmark":
                from repro.kernels import ops as kops
                return kops.fused_preprocess(raw, resize=cfg.resize_src,
                                             crop=cfg.img_size)
            return transforms.preprocess_reference(
                raw, resize=cfg.resize_src, crop=cfg.img_size)

        # ingest consumes the per-image fold_in keys as an input — the
        # derivation itself is image_keys(), shared by every caller.
        # Tile-first: offsets from the keys (static geometry only),
        # then one kernel straight to the decode input.
        def ingest_keyed(raw, keys):
            if self.tile_first:
                from repro.kernels import ops as kops
                offs = tiling.tile_first_offsets(
                    cfg.strategy, keys, img_size=cfg.img_size,
                    tile=cfg.tile)
                return kops.fused_tile_preprocess(
                    raw, offs, resize=cfg.resize_src, crop=cfg.img_size,
                    tile=cfg.tile)
            return preprocess(raw)

        def decode_keyed(x, keys):
            if self.tile_first or cfg.mode == "sequential":
                tiles = x  # tiles from ingest / full-image decode
            else:
                tiles, _ = tiling.select_tiles_per_image(
                    cfg.strategy, keys, x, cfg.tile)
            return extract(tiles)

        # embed-emitting decode: same tile selection, extractor returns
        # (logits, gap_embedding).  The logits ops are identical —
        # asserted by tests — so the serving tier can swap this in for
        # round-0 decode whenever the near-duplicate cache is on
        # without perturbing the bit-identity contract.
        def decode_keyed_embed(x, keys):
            if self.tile_first or cfg.mode == "sequential":
                tiles = x
            else:
                tiles, _ = tiling.select_tiles_per_image(
                    cfg.strategy, keys, x, cfg.tile)
            return extract_embed(tiles)

        self.ingest_keyed = jax.jit(ingest_keyed)
        self.decode_keyed = jax.jit(decode_keyed)
        self.decode_keyed_embed = jax.jit(decode_keyed_embed)
        self.bits = jax.jit(lambda logits: (logits > 0).astype(jnp.int32))

        # -- escalation compute (cfg.escalate_tiles > 1) ---------------
        # The per-image k-tile plan depends only on the keys and static
        # geometry; column 0 is bit-identical to the single-tile draw,
        # so round 1 IS the unmodified fast path and rounds 2..k decode
        # plan columns 1..k-1.
        def plan_fn(keys):
            return tiling.escalation_offsets(
                cfg.strategy, keys, (cfg.img_size, cfg.img_size),
                cfg.tile, self.policy.max_tiles)

        def tiles_at(raw, offs):
            """(b, 2) or (b, k, 2) offsets -> decode-ready tiles, via
            the tile-first kernel or the staged preprocess + extract."""
            if self.tile_first:
                from repro.kernels import ops as kops
                return kops.fused_tile_preprocess(
                    raw, offs, resize=cfg.resize_src, crop=cfg.img_size,
                    tile=cfg.tile)
            x = preprocess(raw)
            if offs.ndim == 3:
                return tiling.extract_tiles_k(x, offs, cfg.tile)
            return tiling.extract_tiles(x, offs, cfg.tile)

        def decode_all_fn(raw, keys):
            p = plan_fn(keys)
            b, kk = p.shape[:2]
            return extract(tiles_at(raw, p)).reshape(b, kk, -1)

        self.escalation_plan = jax.jit(plan_fn)
        # tile r of the escalation plan, decode-ready — the
        # escalation-round ingest for BOTH the inline loop and the
        # server's re-submitted micro-batches (one jitted fn, so the
        # two escalation engines cannot drift).  The round index is
        # TRACED (dynamic_index into the plan), so one compile per
        # sub-batch shape covers every round — which keeps warmup and
        # the first escalation cheap.
        self.escalation_tiles = jax.jit(
            lambda raw, keys, r: tiles_at(raw, plan_fn(keys)[:, r]))
        # decode-ready tiles -> logits (the escalation-round decode)
        self.decode_tiles = jax.jit(extract)
        # all k tiles at once -> (b, k, n_bits): the always-k baseline
        # and the (b, k, 2) kernel fast path
        self.decode_all_keyed = jax.jit(decode_all_fn)

        self._image_keys_jit = jax.jit(
            lambda key, b: jax.vmap(
                lambda i: jax.random.fold_in(key, i))(jnp.arange(b)),
            static_argnums=1)

        if cfg.rs_mode == "device":
            self._device_rs = make_device_rs(self.code)
        elif cfg.rs_mode == "cpu_pool":
            self._rs_pool = RSCorrectionPool(self.code,
                                             n_threads=cfg.rs_threads)

        # fully fused fast path (qrmark + device RS): one jitted graph.
        # The raw-batch buffer is donated — ingest is its only reader,
        # so the runtime can recycle the largest in-flight buffer while
        # decode/RS still run.  CPU cannot reuse a donated uint8 input
        # (it would only warn once per compile), so donation is applied
        # on accelerator backends only.
        if cfg.mode == "qrmark" and cfg.rs_mode == "device":
            dev_decoder = self._device_rs  # one decoder for every engine

            def fused_keyed(raw, keys):
                x = ingest_keyed(raw, keys)
                logits = decode_keyed(x, keys)
                bits = (logits > 0).astype(jnp.int32)
                return dev_decoder(bits), logits

            # escalation re-reads the raw batch after round 1, so the
            # buffer can only be donated when escalation is off
            donate = (() if jax.default_backend() == "cpu"
                      or self.policy.enabled else (0,))
            self.fused_keyed = jax.jit(fused_keyed, donate_argnums=donate)
        else:
            self.fused_keyed = None

    # -- RS correction ---------------------------------------------------
    def _rs_host(self, bits: np.ndarray):
        """(msg, ok, ncorr) via the configured host RS engine."""
        cfg = self.cfg
        b = bits.shape[0]
        msg = np.zeros((b, self.code.message_bits), np.int32)
        ok = np.zeros((b,), bool)
        ncorr = np.zeros((b,), np.int32)
        if cfg.rs_mode == "cpu_pool":
            with self._pool_lock:
                base = self._pool_seq
                self._pool_seq += b
            self._rs_pool.submit_batch(bits, base)
            for i, (mi, oki) in enumerate(
                    self._rs_pool.drain(range(base, base + b))):
                msg[i], ok[i] = mi[: self.code.message_bits], oki
        else:  # cpu_sync
            for i in range(b):
                res = rs_decode(self.code, bits[i])
                msg[i] = res.message_bits
                ok[i] = res.ok
                ncorr[i] = res.n_corrected
        return msg, ok, ncorr

    def rs_correct(self, bits):
        """(msg, ok, ncorr) via the configured RS engine.  ``bits`` stays
        a device array end-to-end on the device path (zero-copy handoff);
        host engines pull it to numpy here, at their host boundary."""
        if self.cfg.rs_mode == "device":
            rs_out = self._device_rs(bits if isinstance(bits, jax.Array)
                                     else jnp.asarray(bits))
            return (rs_out["message_bits"], rs_out["ok"],
                    rs_out["n_corrected"])
        return self._rs_host(np.asarray(bits))

    # -- adaptive multi-tile escalation --------------------------------
    def escalate_round(self, raw, keys, r: int):
        """Soft bits of escalation-plan tile ``r``: the two jitted
        escalation stage fns composed — literally the fns the server's
        re-submitted rounds run, so the inline loop and the online
        escalation path cannot drift bitwise."""
        return self.decode_tiles(self.escalation_tiles(raw, keys, r))

    def escalate(self, raw, keys, msg, ok, ncorr, logits
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                            np.ndarray, np.ndarray]:
        """Adaptive escalation after a completed round 1: images whose
        RS failed (or whose margin is thin — :class:`EscalationPolicy`)
        are re-decoded on tile r of their plan each round, soft bits
        (logits) are ACCUMULATED across tiles, and RS re-runs on the
        accumulated signs, until every image settles or the
        ``max_tiles`` budget is spent.

        Host-orchestrated: each round gathers only the still-failing
        images into a pow2-padded sub-batch (bounded jit shapes) and
        drives the same jitted tile/decode/RS engines as round 1, so
        per-image results are bit-identical no matter which engine ran
        round 1 or how failures were sub-batched (every op in the path
        is batch-stable).  Returns (msg, ok, ncorr, accumulated_logits,
        tiles_used) as numpy arrays; with ``escalate_tiles == 1`` the
        inputs pass through untouched (tiles_used all ones)."""
        b = np.asarray(ok).shape[0]
        tiles_used = np.ones(b, np.int32)
        if not self.policy.enabled:
            return (np.asarray(msg), np.asarray(ok), np.asarray(ncorr),
                    np.asarray(logits), tiles_used)
        msg = np.asarray(msg).copy()
        ok = np.asarray(ok).copy()
        ncorr = np.asarray(ncorr).copy()
        acc = np.asarray(logits, np.float32).copy()
        raw_np = np.asarray(raw)
        need = self.policy.wants_escalation(ok, acc)
        for r in range(1, self.policy.max_tiles):
            idx = np.nonzero(need)[0]
            if idx.size == 0:
                break
            sub_raw, n = _pad_pow2(raw_np[idx])
            sub_keys, _ = _pad_pow2(keys[idx])
            new_logits = np.asarray(
                self.escalate_round(sub_raw, sub_keys, r))[:n]
            acc[idx] += new_logits
            sub_acc, _ = _pad_pow2(acc[idx])
            m2, o2, c2 = self.rs_correct(
                (sub_acc > 0).astype(np.int32))
            m2, o2, c2 = (np.asarray(a)[:n] for a in (m2, o2, c2))
            msg[idx], ok[idx], ncorr[idx] = m2, o2, c2
            tiles_used[idx] = r + 1
            need[:] = False
            need[idx] = self.policy.wants_escalation(o2, acc[idx])
        return msg, ok, ncorr, acc, tiles_used

    def escalate_prefix(self, raw, keys, msg, ok, ncorr, logits,
                        true_b: Optional[int] = None):
        """:meth:`escalate` restricted to the first ``true_b`` rows of
        a padded batch: pad rows (repeats of the last real image) keep
        their round-1 results and never consume escalation rounds.
        Returns full-size arrays either way — the one scatter shared by
        ``detect_batch`` and the stage-graph rs sink."""
        b = np.asarray(ok).shape[0]
        tb = b if true_b is None else min(true_b, b)
        if tb >= b:
            return self.escalate(raw, keys, msg, ok, ncorr, logits)
        m, o, c, lg, tu = self.escalate(
            raw[:tb], keys[:tb], msg[:tb], ok[:tb], ncorr[:tb],
            logits[:tb])
        msg = np.asarray(msg).copy()
        ok = np.asarray(ok).copy()
        ncorr = np.asarray(ncorr).copy()
        logits = np.asarray(logits, np.float32).copy()
        tiles = np.ones(b, np.int32)
        msg[:tb], ok[:tb], ncorr[:tb] = m, o, c
        logits[:tb], tiles[:tb] = lg, tu
        return msg, ok, ncorr, logits, tiles

    # -- the stage graph ---------------------------------------------------
    def build_stages(self, lanes: Dict[str, int],
                     finish: Optional[Callable[[dict], Any]] = None,
                     depth: int = 2,
                     escalate_inline: bool = True,
                     emit_embed: bool = False
                     ) -> List[lanes_lib.Stage]:
        """The detection stage graph — THE payload contract every
        executor-driven engine (offline run_stream, online server)
        shares.

        Payloads are dicts carrying ``raw`` + ``keys`` (per-image
        fold_in keys, pre-derived by the feeder/batcher so stage
        functions are pure and any lane count or arrival interleaving
        is bit-identical to serial) -> ``x`` -> ``logits`` ->
        ``msg``/``ok``/``ncorr``.  Between lanes everything stays a
        device array (jitted stage fns return futures); ``finish(p)``
        is the sink — the one place device arrays should become numpy.
        Extra payload fields (request slots, timestamps) flow through
        untouched.

        Escalation: payloads may carry ``round`` (int, default 0) and
        ``acc_logits``.  A round-r > 0 payload ingests tile r of each
        image's escalation plan and decode ADDS the new soft bits onto
        ``acc_logits`` — the form the online server's re-submitted
        escalation micro-batches take.  With ``escalate_inline=True``
        (the offline engines) round-0 payloads instead run the whole
        adaptive loop synchronously on the rs lane via
        :meth:`escalate`, annotating the payload with ``tiles_used``.

        ``emit_embed=True`` (the server with the near-duplicate cache
        on) makes round-0 decode also emit the GAP embedding as payload
        field ``embed`` — logits are bitwise unchanged."""

        def st_ingest(p):
            r = p.get("round", 0)
            raw = jax.device_put(p["raw"])
            if r > 0:
                # escalation round: ingest emits tile r of the plan
                # directly (decode-ready), whatever the ingest mode
                p["x"] = self.escalation_tiles(raw, p["keys"], r)
            else:
                p["x"] = self.ingest_keyed(raw, p["keys"])
            return p

        def st_decode(p):
            if p.get("round", 0) > 0:
                logits = self.decode_tiles(p["x"])
            elif emit_embed:
                logits, p["embed"] = self.decode_keyed_embed(
                    p["x"], p["keys"])
            else:
                logits = self.decode_keyed(p["x"], p["keys"])
            if p.get("acc_logits") is not None:
                logits = logits + jnp.asarray(p["acc_logits"])
            p["logits"] = logits
            return p

        def st_rs(p):
            p["msg"], p["ok"], p["ncorr"] = self.rs_correct(
                self.bits(p["logits"]))
            if (escalate_inline and self.policy.enabled
                    and p.get("round", 0) == 0):
                # payloads from padded feeders carry "true_b": only the
                # real rows escalate (pad rows repeat the last real
                # image — escalating them would multiply every round's
                # decode/RS work by the pad factor for nothing; the
                # consumer slices them off anyway)
                (p["msg"], p["ok"], p["ncorr"], p["logits"],
                 p["tiles_used"]) = self.escalate_prefix(
                    p["raw"], p["keys"], p["msg"], p["ok"], p["ncorr"],
                    p["logits"], p.get("true_b"))
            return finish(p) if finish is not None else p

        return [
            lanes_lib.Stage("ingest", st_ingest,
                            lanes=max(1, lanes.get("ingest", 1)),
                            depth=depth),
            lanes_lib.Stage("decode", st_decode,
                            lanes=max(1, lanes.get("decode", 1)),
                            depth=depth, gpu_intensive=True),
            lanes_lib.Stage("rs", st_rs,
                            lanes=max(1, lanes.get("rs", 1)),
                            depth=depth),
        ]

    def close(self):
        if self._rs_pool is not None:
            self._rs_pool.close()
            self._rs_pool = None
