"""Unified stage registry — the single definition of the detection
stage functions (QRMark §5.1/§6.2).

Every execution engine derives its compute from one
:class:`StageRegistry` built once per (config, params):

* ``DetectionPipeline.detect_batch`` — the keyed staged fns, or the
  fully fused single-jit fast path (``fused_keyed``);
* ``DetectionPipeline.build_stages`` / ``run_stream`` — the payload
  stage graph (:meth:`StageRegistry.build_stages`) for the lane
  executor;
* ``DetectionPipeline.run_batch`` — the same keyed staged fns over a
  sharded batch;
* ``serving.DetectionServer`` — the same payload stage graph, driven by
  a long-lived service-mode executor.

Before this module the ingest/decode/RS bodies were restated in four
places inside ``core/detect.py``; now they exist exactly once.

RNG-key discipline (the bit-identity contract): offline, batch k uses
``fold_in(key(seed), k)`` and image i of that batch uses
``fold_in(batch_key, i)``.  Key *derivation* is its own jitted function
(:meth:`image_keys`) and every stage function takes the derived
per-image key array as an explicit input — ``fold_in`` is integer
hashing, bit-exact wherever it runs, so a caller that supplies keys
from somewhere else (the online server derives them per *request*, not
per coalesced batch) gets results bit-identical to the offline engines
on the same images with the same keys, no matter how requests were
batched together.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import extractor as extractor_lib
from repro.core import lanes as lanes_lib, tiling, transforms
from repro.core.extractor import extractor_forward
from repro.core.rs.codec import RSCode, rs_decode
from repro.core.rs import jax_rs
from repro.core.rs.cpu_pool import RSCorrectionPool

STAGE_NAMES = ("ingest", "decode", "rs")

# the code the Pallas Berlekamp-Welch kernel is specialised for
_PALLAS_RS_CODE = (4, 15, 12)  # (m, n, k)


def make_device_rs(code: RSCode) -> Callable:
    """The on-device batched RS engine: the Pallas Berlekamp-Welch
    kernel for the code it is specialised for, ``jax_rs`` otherwise.
    Jit-able and safe to inline into a larger jitted graph — every
    engine (fused fast path, lane executor, sharded run_batch, online
    server) must use the same decoder so failure tie-breaking never
    diverges."""
    if (code.m, code.n, code.k) == _PALLAS_RS_CODE:
        from repro.kernels import ops as kops

        def decode(bits):
            return kops.rs_decode(bits, code=code)

        # jitted so sharded inputs (run_batch) go through the SPMD
        # partitioner instead of eager multi-device dispatch
        return jax.jit(decode)
    return jax_rs.make_batch_decoder(code)


class StageRegistry:
    """The detection stage functions, built once per (cfg, params).

    Holds the jitted keyed stage fns, the packed decode weights, the
    configured RS engine (including the CPU pool's state), and the
    fused fast path.  Engine objects (pipeline, server) own a registry
    and derive everything from it."""

    def __init__(self, cfg, params):
        if cfg.mode not in ("sequential", "tiled", "qrmark"):
            raise ValueError(f"unknown pipeline mode {cfg.mode!r}")
        if cfg.rs_mode not in ("device", "cpu_pool", "cpu_sync"):
            raise ValueError(f"unknown rs_mode {cfg.rs_mode!r}")
        if cfg.decode_dtype not in extractor_lib.DECODE_DTYPES:
            raise ValueError(f"unknown decode_dtype {cfg.decode_dtype!r}")
        self.cfg = cfg
        self.params = params
        self.code = cfg.code
        self.base_key = jax.random.key(cfg.seed)
        self.tile_first = (cfg.tile_first and cfg.mode == "qrmark"
                           and cfg.fused_preprocess)
        self.fused_decode = cfg.fused_decode and cfg.mode == "qrmark"
        self._rs_pool: Optional[RSCorrectionPool] = None
        self._device_rs = None
        self._pool_seq = 0            # RS-pool job id counter
        self._pool_lock = threading.Lock()
        self._build()

    # -- RNG-key discipline --------------------------------------------
    def batch_key(self, seq: int):
        """Offline key for batch ``seq``: fold_in(key(cfg.seed), seq)."""
        return jax.random.fold_in(self.base_key, seq)

    def image_keys(self, key, b: int):
        """Per-image keys fold_in(key, 0..b-1) — THE derivation every
        engine shares (jitted per b; fold_in is bit-exact regardless of
        the enclosing graph, so deriving here vs inline is identical)."""
        return self._image_keys_jit(key, b)

    # -- build ----------------------------------------------------------
    def _build(self):
        cfg = self.cfg

        # decode-stage extractor, one fn for every engine: the fused
        # Pallas kernel on pre-packed params (qrmark; pack once per
        # registry build, dtype = the precision policy) or the unfused
        # extractor_forward graph (bit-identical to the fp32 kernel —
        # they share extractor_forward_packed)
        if self.fused_decode:
            from repro.kernels import ops as kops
            self.packed_params = extractor_lib.pack_params(
                self.params, cfg.decode_dtype)

            def extract(tiles):
                return kops.fused_extractor(tiles, self.packed_params)
        else:
            self.packed_params = None

            def extract(tiles):
                return extractor_forward(self.params, tiles)

        def preprocess(raw):
            if cfg.fused_preprocess and cfg.mode == "qrmark":
                from repro.kernels import ops as kops
                return kops.fused_preprocess(raw, resize=cfg.resize_src,
                                             crop=cfg.img_size)
            return transforms.preprocess_reference(
                raw, resize=cfg.resize_src, crop=cfg.img_size)

        # ingest consumes the per-image fold_in keys as an input — the
        # derivation itself is image_keys(), shared by every caller.
        # Tile-first: offsets from the keys (static geometry only),
        # then one kernel straight to the decode input.
        def ingest_keyed(raw, keys):
            if self.tile_first:
                from repro.kernels import ops as kops
                offs = tiling.tile_first_offsets(
                    cfg.strategy, keys, img_size=cfg.img_size,
                    tile=cfg.tile)
                return kops.fused_tile_preprocess(
                    raw, offs, resize=cfg.resize_src, crop=cfg.img_size,
                    tile=cfg.tile)
            return preprocess(raw)

        def decode_keyed(x, keys):
            if self.tile_first or cfg.mode == "sequential":
                tiles = x  # tiles from ingest / full-image decode
            else:
                tiles, _ = tiling.select_tiles_per_image(
                    cfg.strategy, keys, x, cfg.tile)
            return extract(tiles)

        self.ingest_keyed = jax.jit(ingest_keyed)
        self.decode_keyed = jax.jit(decode_keyed)
        self.bits = jax.jit(lambda logits: (logits > 0).astype(jnp.int32))
        self._image_keys_jit = jax.jit(
            lambda key, b: jax.vmap(
                lambda i: jax.random.fold_in(key, i))(jnp.arange(b)),
            static_argnums=1)

        if cfg.rs_mode == "device":
            self._device_rs = make_device_rs(self.code)
        elif cfg.rs_mode == "cpu_pool":
            self._rs_pool = RSCorrectionPool(self.code,
                                             n_threads=cfg.rs_threads)

        # fully fused fast path (qrmark + device RS): one jitted graph.
        # The raw-batch buffer is donated — ingest is its only reader,
        # so the runtime can recycle the largest in-flight buffer while
        # decode/RS still run.  CPU cannot reuse a donated uint8 input
        # (it would only warn once per compile), so donation is applied
        # on accelerator backends only.
        if cfg.mode == "qrmark" and cfg.rs_mode == "device":
            dev_decoder = self._device_rs  # one decoder for every engine

            def fused_keyed(raw, keys):
                x = ingest_keyed(raw, keys)
                logits = decode_keyed(x, keys)
                bits = (logits > 0).astype(jnp.int32)
                return dev_decoder(bits), logits

            donate = () if jax.default_backend() == "cpu" else (0,)
            self.fused_keyed = jax.jit(fused_keyed, donate_argnums=donate)
        else:
            self.fused_keyed = None

    # -- RS correction ---------------------------------------------------
    def _rs_host(self, bits: np.ndarray):
        """(msg, ok, ncorr) via the configured host RS engine."""
        cfg = self.cfg
        b = bits.shape[0]
        msg = np.zeros((b, self.code.message_bits), np.int32)
        ok = np.zeros((b,), bool)
        ncorr = np.zeros((b,), np.int32)
        if cfg.rs_mode == "cpu_pool":
            with self._pool_lock:
                base = self._pool_seq
                self._pool_seq += b
            self._rs_pool.submit_batch(bits, base)
            for i, (mi, oki) in enumerate(
                    self._rs_pool.drain(range(base, base + b))):
                msg[i], ok[i] = mi[: self.code.message_bits], oki
        else:  # cpu_sync
            for i in range(b):
                res = rs_decode(self.code, bits[i])
                msg[i] = res.message_bits
                ok[i] = res.ok
                ncorr[i] = res.n_corrected
        return msg, ok, ncorr

    def rs_correct(self, bits):
        """(msg, ok, ncorr) via the configured RS engine.  ``bits`` stays
        a device array end-to-end on the device path (zero-copy handoff);
        host engines pull it to numpy here, at their host boundary."""
        if self.cfg.rs_mode == "device":
            rs_out = self._device_rs(bits if isinstance(bits, jax.Array)
                                     else jnp.asarray(bits))
            return (rs_out["message_bits"], rs_out["ok"],
                    rs_out["n_corrected"])
        return self._rs_host(np.asarray(bits))

    # -- the stage graph ---------------------------------------------------
    def build_stages(self, lanes: Dict[str, int],
                     finish: Optional[Callable[[dict], Any]] = None,
                     depth: int = 2) -> List[lanes_lib.Stage]:
        """The detection stage graph — THE payload contract every
        executor-driven engine (offline run_stream, online server)
        shares.

        Payloads are dicts carrying ``raw`` + ``keys`` (per-image
        fold_in keys, pre-derived by the feeder/batcher so stage
        functions are pure and any lane count or arrival interleaving
        is bit-identical to serial) -> ``x`` -> ``logits`` ->
        ``msg``/``ok``/``ncorr``.  Between lanes everything stays a
        device array (jitted stage fns return futures); ``finish(p)``
        is the sink — the one place device arrays should become numpy.
        Extra payload fields (request slots, timestamps) flow through
        untouched."""

        def st_ingest(p):
            p["x"] = self.ingest_keyed(jax.device_put(p["raw"]),
                                       p["keys"])
            return p

        def st_decode(p):
            p["logits"] = self.decode_keyed(p["x"], p["keys"])
            return p

        def st_rs(p):
            p["msg"], p["ok"], p["ncorr"] = self.rs_correct(
                self.bits(p["logits"]))
            return finish(p) if finish is not None else p

        return [
            lanes_lib.Stage("ingest", st_ingest,
                            lanes=max(1, lanes.get("ingest", 1)),
                            depth=depth),
            lanes_lib.Stage("decode", st_decode,
                            lanes=max(1, lanes.get("decode", 1)),
                            depth=depth, gpu_intensive=True),
            lanes_lib.Stage("rs", st_rs,
                            lanes=max(1, lanes.get("rs", 1)),
                            depth=depth),
        ]

    def close(self):
        if self._rs_pool is not None:
            self._rs_pool.close()
            self._rs_pool = None
