"""Image transforms: preprocessing ops (Table 1) + evaluation attacks.

Everything is pure JAX so the whole detection pipeline (and the training
transform set T) stays on device.  ``jpeg`` is the standard blockwise
DCT-quantisation surrogate (differentiable, matmul-form — TPU-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# preprocessing (QRMark Table 1, Preprocess stage)
# ---------------------------------------------------------------------------


def resize_to(images, size: int):
    b, h, w, c = images.shape
    return jax.image.resize(images, (b, size, size, c), method="bilinear")


def center_crop(images, size: int):
    b, h, w, c = images.shape
    y0, x0 = (h - size) // 2, (w - size) // 2
    return images[:, y0: y0 + size, x0: x0 + size, :]


IMAGENET_MEAN = np.array([0.485, 0.456, 0.406], np.float32)
IMAGENET_STD = np.array([0.229, 0.224, 0.225], np.float32)


def normalize(images, mean=None, std=None):
    """uint8/float [0,1] -> VQGAN-ish normalised float."""
    mean = IMAGENET_MEAN if mean is None else mean
    std = IMAGENET_STD if std is None else std
    x = images.astype(jnp.float32)
    return (x - jnp.asarray(mean)) / jnp.asarray(std)


def preprocess_reference(raw, *, resize: int = 288, crop: int = 256,
                         mean=None, std=None):
    """Unfused Resize -> CenterCrop -> Normalize (the fragmented-kernel
    baseline the paper profiles; the Pallas kernel fuses this)."""
    x = raw.astype(jnp.float32) / 255.0
    x = resize_to(x, resize)
    x = center_crop(x, crop)
    return normalize(x, mean, std)


# ---------------------------------------------------------------------------
# evaluation attacks (QRMark Table 1, Evaluation stage)
# ---------------------------------------------------------------------------


def attack_crop(images, frac: float):
    """Keep the central ``frac`` of the area, resize back."""
    b, h, w, c = images.shape
    keep = max(int(round((frac ** 0.5) * h)), 4)
    x = center_crop(images, keep)
    return jax.image.resize(x, (b, h, w, c), method="bilinear")


def attack_resize(images, frac: float):
    b, h, w, c = images.shape
    nh, nw = max(int(h * frac), 4), max(int(w * frac), 4)
    x = jax.image.resize(images, (b, nh, nw, c), method="bilinear")
    return jax.image.resize(x, (b, h, w, c), method="bilinear")


def attack_brightness(images, factor: float):
    return jnp.clip(images * factor, -3.0, 3.0)


def attack_contrast(images, factor: float):
    mu = images.mean(axis=(1, 2, 3), keepdims=True)
    return jnp.clip(mu + (images - mu) * factor, -3.0, 3.0)


def attack_saturation(images, factor: float):
    grey = images.mean(axis=-1, keepdims=True)
    return jnp.clip(grey + (images - grey) * factor, -3.0, 3.0)


def attack_sharpness(images, factor: float):
    blur = attack_blur(images)
    return jnp.clip(blur + (images - blur) * factor, -3.0, 3.0)


def attack_blur(images, k: int = 3):
    c = images.shape[-1]
    kern = jnp.ones((k, k, 1, 1), jnp.float32) / (k * k)
    kern = jnp.tile(kern, (1, 1, 1, c))
    return jax.lax.conv_general_dilated(
        images, kern, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)


@functools.lru_cache(maxsize=None)
def _dct8():
    # NOTE: must return numpy (a cached jnp array created inside a jit
    # trace would leak a tracer into later calls)
    k = np.arange(8)
    n = np.arange(8)
    D = np.sqrt(2 / 8) * np.cos(np.pi * (2 * n[None] + 1) * k[:, None] / 16)
    D[0] /= np.sqrt(2)
    return D.astype(np.float32)


# luminance quantisation table (JPEG Annex K), quality-scaled
_QTAB = np.array(
    [[16, 11, 10, 16, 24, 40, 51, 61], [12, 12, 14, 19, 26, 58, 60, 55],
     [14, 13, 16, 24, 40, 57, 69, 56], [14, 17, 22, 29, 51, 87, 80, 62],
     [18, 22, 37, 56, 68, 109, 103, 77], [24, 35, 55, 64, 81, 104, 113, 92],
     [49, 64, 78, 87, 103, 121, 120, 101],
     [72, 92, 95, 98, 112, 100, 103, 99]], np.float32)


def attack_jpeg(images, quality: int = 50):
    """Blockwise DCT quantisation surrogate of JPEG compression."""
    b, h, w, c = images.shape
    hp, wp = -(-h // 8) * 8, -(-w // 8) * 8
    x = jnp.pad(images, ((0, 0), (0, hp - h), (0, wp - w), (0, 0)),
                mode="edge")
    scale = 50.0 / quality if quality < 50 else 2 - quality / 50.0
    q = jnp.maximum(jnp.asarray(_QTAB) * scale, 1.0) / 128.0
    D = jnp.asarray(_dct8())
    blocks = x.reshape(b, hp // 8, 8, wp // 8, 8, c)
    coef = jnp.einsum("ij,bhjwkc,lk->bhiwlc", D, blocks, D)
    coef = jnp.round(coef / q[None, None, :, None, :, None]) \
        * q[None, None, :, None, :, None]
    rec = jnp.einsum("ji,bhjwkc,kl->bhiwlc", D, coef, D)
    return rec.reshape(b, hp, wp, c)[:, :h, :w, :]


def attack_overlay_text(images, intensity: float = 1.0):
    """Overlay a fixed block pattern simulating burned-in text."""
    b, h, w, c = images.shape
    yy, xx = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    band = (yy > h * 3 // 4) & (yy < h * 7 // 8)
    glyph = ((xx // 6) % 2 == 0) & ((xx > w // 8) & (xx < w * 7 // 8))
    mask = (band & glyph).astype(jnp.float32)[None, :, :, None]
    return images * (1 - mask) + intensity * mask


ATTACKS = {
    "none": lambda x: x,
    "crop_0.1": lambda x: attack_crop(x, 0.1),
    "crop_0.5": lambda x: attack_crop(x, 0.5),
    "resize_0.5": lambda x: attack_resize(x, 0.5),
    "resize_0.7": lambda x: attack_resize(x, 0.7),
    "blur": attack_blur,
    "brightness_2": lambda x: attack_brightness(x, 2.0),
    "contrast_2": lambda x: attack_contrast(x, 2.0),
    "saturation_2": lambda x: attack_saturation(x, 2.0),
    "sharpness_2": lambda x: attack_sharpness(x, 2.0),
    "jpeg_50": lambda x: attack_jpeg(x, 50),
    "overlay_text": attack_overlay_text,
}

# the paper's Stable-Signature adversarial set (Table 2 "Adv." column)
STABLE_SIG_ATTACKS = ("crop_0.5", "resize_0.7", "jpeg_50", "brightness_2",
                      "contrast_2", "saturation_2", "sharpness_2",
                      "overlay_text")
