"""HiDDeN-style watermark encoder H_E and tile extractor H_D (QRMark §4.1).

Pure-JAX conv nets (NHWC).  The encoder embeds an N-bit message into an
l x l tile as a residual (x_w = x_0 + alpha * delta, ReDMark-style); the
extractor recovers soft bit logits from a (possibly transformed) tile.
Both are small enough to train on CPU at reduced scale and are the
"decode" stage of the detection pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def conv_init(key, kh, kw, cin, cout, scale=None):
    scale = scale or (2.0 / (kh * kw * cin)) ** 0.5
    return scale * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)


def conv2d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def channel_norm(x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _block(params, x):
    x = conv2d(x, params["w"]) + params["b"]
    return jax.nn.relu(channel_norm(x))


# ---------------------------------------------------------------------------
# extractor H_D
# ---------------------------------------------------------------------------


def init_extractor(key, *, n_bits: int, channels: int = 64,
                   depth: int = 7, tile: int = 0,
                   patterns: "jnp.ndarray" = None) -> dict:
    """HiDDeN-style conv extractor + a spread-spectrum correlation path.

    The correlation bank (init tied to the encoder's pattern bank when
    given) makes the 60-bit code linearly decodable from step 0; the conv
    stack learns the nonlinear robustness corrections under attacks.
    This warm-start is the CPU-scale adaptation recorded in DESIGN.md —
    at paper scale the conv path alone trains to the same point."""
    ks = jax.random.split(key, depth + 4)
    blocks = []
    cin = 3
    for i in range(depth):
        blocks.append({"w": conv_init(ks[i], 3, 3, cin, channels),
                       "b": jnp.zeros((channels,))})
        cin = channels
    p = {
        "blocks": blocks,
        "to_bits": {"w": conv_init(ks[depth], 3, 3, channels, n_bits),
                    "b": jnp.zeros((n_bits,))},
        "head": {"w": dense_init(ks[depth + 1], (n_bits, n_bits),
                                 scale=0.2),
                 "b": jnp.zeros((n_bits,))},
    }
    if tile:
        if patterns is None:
            patterns = pattern_bank(ks[depth + 2], n_bits, tile)
        p["corr"] = patterns
        p["corr_scale"] = jnp.ones((n_bits,))
    return p


def pattern_bank(key, n_bits: int, tile: int):
    """Unit-norm white patterns, one per bit."""
    P = jax.random.normal(key, (n_bits, tile, tile, 3), jnp.float32)
    P = P - P.mean(axis=(1, 2, 3), keepdims=True)
    return P / jnp.sqrt(jnp.sum(jnp.square(P), axis=(1, 2, 3),
                                keepdims=True))


def highpass(x):
    """Remove local mean (3x3): image content is low-frequency, the
    spread-spectrum watermark is white — classic correlation denoising."""
    c = x.shape[-1]
    k = jnp.ones((3, 3, 1, 1), jnp.float32) / 9.0
    k = jnp.tile(k, (1, 1, 1, c))
    blur = jax.lax.conv_general_dilated(
        x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)
    return x - blur


# -- matmul-form forward: the one body shared by the unfused XLA path
# -- and the fused Pallas decode kernel (kernels/fused_extractor.py)

DECODE_DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16,
                 "int8": jnp.int8}

INT8_QMAX = 127.0


def quantize_weight_int8(w2d):
    """(K, N) fp32 weight -> (int8 weight, fp32 per-output-channel
    scale (N,)): symmetric per-channel quantization, the static half of
    the int8 decode rung (computed once at ``pack_params`` time)."""
    scale = jnp.maximum(jnp.abs(w2d).max(axis=0),
                        jnp.float32(1e-8)) / INT8_QMAX
    q = jnp.clip(jnp.round(w2d / scale), -INT8_QMAX,
                 INT8_QMAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_rows_int8(x2d):
    """(M, K) fp32 activations -> (int8, fp32 per-row scale (M, 1)):
    the dynamic half of the int8 rung.  Per-ROW scales keep the op
    batch-stable (row i of a size-b batch quantizes exactly as it would
    alone), which the ragged-serving/bit-identity contract needs."""
    s = jnp.maximum(jnp.abs(x2d).max(axis=1, keepdims=True),
                    jnp.float32(1e-8)) / INT8_QMAX
    q = jnp.clip(jnp.round(x2d / s), -INT8_QMAX,
                 INT8_QMAX).astype(jnp.int8)
    return q, s


def _shifts3x3(x):
    """The nine 3x3-tap shifted views of x (b, h, w, c), zero padding,
    [ky, kx] order — the implicit im2col a SAME 3x3 conv reads."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    return [xp[:, dy: dy + h, dx: dx + w, :]
            for dy in range(3) for dx in range(3)]


def tap_dot(xs2d, w2d, tap, cin, scale=None):
    """One tap's dot: (M, cin) shifted view x rows [tap*cin, (tap+1)*cin)
    of a packed weight -> (M, cout), fp32 result.

    THE per-tap primitive every decode path shares (the unfused graph,
    the flat Pallas kernel, and the blocked kernel all accumulate these
    in the same static tap order, which the bit-identity contract
    depends on).  fp32/bf16 weights: cast input, MXU dot, fp32
    accumulation.  int8 weights (``scale`` = the per-output-channel
    dequant scale, column-sliced the same way as ``w2d`` when the
    caller channel-tiles): dynamic per-row activation quantization,
    int8 x int8 -> int32 dot, fp32 dequantize — so the int8 partial
    sums join the same fp32 left-fold as the other rungs."""
    wt = w2d[tap * cin: (tap + 1) * cin]
    if w2d.dtype == jnp.int8:
        xq, s = quantize_rows_int8(xs2d)
        y = jax.lax.dot_general(xq, wt, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
        return y.astype(jnp.float32) * s * scale[None, :]
    return jnp.dot(xs2d.astype(w2d.dtype), wt,
                   preferred_element_type=jnp.float32)


def conv3x3_mm(x, w2d, scale=None):
    """SAME 3x3 conv as nine accumulated MXU matmuls: x (b, h, w, c) x
    packed weight (9c, cout) -> (b*h*w, cout), fp32 accumulation.

    Tap-accumulated rather than one materialised (b*h*w, 9c) im2col
    matmul, so the live working set stays activation-sized (the
    full-image sequential path and training also run this body).  Tap
    order is static, every tap dot keeps M = b*h*w, and the nine
    partial sums add elementwise — all batch-stable, which the
    fused/unfused bit-identity contract depends on.  ``scale`` carries
    the int8 rung's per-channel dequant scales (see :func:`tap_dot`)."""
    b, h, w, c = x.shape
    acc = None
    for tap, xs in enumerate(_shifts3x3(x)):
        y = tap_dot(xs.reshape(b * h * w, c), w2d, tap, c, scale)
        acc = y if acc is None else acc + y
    return acc


def _box3x3(x):
    """3x3 box blur, zero padding — the mean ``highpass`` subtracts,
    as the same nine-tap sum the conv path uses (shared, so the
    kernel's and the unfused graph's blur cannot drift)."""
    acc = None
    for xs in _shifts3x3(x):
        acc = xs if acc is None else acc + xs
    return acc * (1.0 / 9.0)


def pack_params(params, dtype="fp32"):
    """Extractor params -> the matmul-friendly layout the decode path
    consumes (built once per pipeline; :func:`extractor_forward_packed`
    and the Pallas kernel both read this form).

    Matmul operands (block/to_bits/head weights, correlation bank) are
    stored in the compute ``dtype`` ("fp32" or "bf16" — the MXU input
    precision); every epilogue term (biases, corr_scale) stays fp32
    because accumulation and the norm/ReLU epilogue always run in
    fp32.

    "int8" is the lowest rung of the precision ladder: conv/to_bits
    weights quantize symmetrically per output channel at pack time
    (``quantize_weight_int8``, the scale rides along as a fp32
    ``"scale"`` leaf), while head + correlation — a negligible FLOP
    slice but the decision-critical epilogue — stay fp32."""
    cdt = DECODE_DTYPES[dtype] if isinstance(dtype, str) else dtype

    def conv_entry(w4d, bias):
        w2d = w4d.reshape(-1, w4d.shape[-1])
        if cdt == jnp.int8:
            q, scale = quantize_weight_int8(w2d.astype(jnp.float32))
            return {"w": q, "scale": scale,
                    "b": bias.astype(jnp.float32)}
        return {"w": w2d.astype(cdt), "b": bias.astype(jnp.float32)}

    # the head (and corr bank below) stay fp32 in int8 packs
    hdt = jnp.float32 if cdt == jnp.int8 else cdt
    pk = {
        "blocks": [conv_entry(b["w"], b["b"]) for b in params["blocks"]],
        "to_bits": conv_entry(params["to_bits"]["w"],
                              params["to_bits"]["b"]),
        "head": {"w": params["head"]["w"].astype(hdt),
                 "b": params["head"]["b"].astype(jnp.float32)},
    }
    if "corr" in params:
        n, t = params["corr"].shape[0], params["corr"].shape[1]
        # (n, t, t, 3) -> (t*t, n, 3): pixel-major so the correlation
        # reduces over (pixel, channel) with batch-stable shapes
        pk["corr"] = params["corr"].transpose(1, 2, 0, 3).reshape(
            t * t, n, 3).astype(hdt)
        pk["corr_scale"] = params["corr_scale"].astype(jnp.float32)
    return pk


def _dequant_w(entry):
    w = entry["w"].astype(jnp.float32)
    if entry["w"].dtype == jnp.int8:
        w = w * entry["scale"][None, :]
    return w


def unpack_params(packed):
    """Exact inverse of :func:`pack_params` for fp32 packs (bf16 packs
    round-trip to the bf16-rounded weights, int8 packs to the
    dequantized q * scale weights)."""
    cin = 3
    blocks = []
    for blk in packed["blocks"]:
        cout = blk["w"].shape[-1]
        blocks.append({"w": _dequant_w(blk).reshape(3, 3, cin, cout),
                       "b": blk["b"]})
        cin = cout
    nb = packed["to_bits"]["w"].shape[-1]
    p = {
        "blocks": blocks,
        "to_bits": {"w": _dequant_w(packed["to_bits"]).reshape(
            3, 3, cin, nb),
            "b": packed["to_bits"]["b"]},
        "head": {"w": packed["head"]["w"].astype(jnp.float32),
                 "b": packed["head"]["b"]},
    }
    if "corr" in packed:
        t2, n, _ = packed["corr"].shape
        t = int(round(t2 ** 0.5))
        p["corr"] = packed["corr"].astype(jnp.float32).reshape(
            t, t, n, 3).transpose(2, 0, 1, 3)
        p["corr_scale"] = packed["corr_scale"]
    return p


def extractor_forward_packed_embed(packed, tiles):
    """:func:`extractor_forward_packed` that additionally returns the
    GAP vector ``g`` — the to_bits global-average-pooled features the
    head consumes.  ``g`` is the serving tier's near-duplicate
    embedding (``serving.cache.EmbeddingCache``): it already exists on
    the logits path, so exposing it costs one extra kernel output and
    zero extra arithmetic, and the logits are computed by the exact
    same ops either way (bitwise identical to the embed-free call).

    This is THE shared body: ``extractor_forward`` (the unfused XLA
    graph) and the Pallas kernel grid step (block shape (1, l, l, 3))
    both run it verbatim, so the fused/unfused bit-identity contract
    cannot silently drift — and every op is *batch-stable* (a size-b
    batch computes row i exactly as a size-1 batch would):

    * conv matmuls keep M = b*l*l (slice-stable GEMM shapes), with the
      nine taps accumulated in static order (``conv3x3_mm``);
    * GAP is a (1, 2)-axis mean with the batch dim leading;
    * head and correlation contract via broadcast-multiply + reduce
      instead of M=b GEMV/GEMM dots, whose K-accumulation order is
      batch-dependent on some backends (they are a negligible slice of
      decode FLOPs).

    Matmul inputs are cast to the packed compute dtype; accumulation
    (``preferred_element_type``), the highpass (elementwise VPU work)
    and the epilogue stay fp32.  int8 packs route their conv matmuls
    through the quantized ``tap_dot`` path (head/corr read the pack's
    fp32 head dtype, so the fp32/bf16 graphs are untouched).
    """
    b, l = tiles.shape[0], tiles.shape[1]
    cdt = packed["head"]["w"].dtype
    x = tiles
    for blk in packed["blocks"]:
        y = conv3x3_mm(x, blk["w"], blk.get("scale"))
        x = jax.nn.relu(channel_norm(
            y.reshape(b, l, l, -1) + blk["b"]))
    y = conv3x3_mm(x, packed["to_bits"]["w"],
                   packed["to_bits"].get("scale"))
    y = y.reshape(b, l, l, -1) + packed["to_bits"]["b"]
    g = y.mean(axis=(1, 2))  # GAP
    logits = (g.astype(cdt)[:, :, None] * packed["head"]["w"][None]
              ).astype(jnp.float32).sum(axis=1) + packed["head"]["b"]
    if "corr" in packed and packed["corr"].shape[0] == l * l:
        # correlation path only at the bank's native tile size (the conv
        # path alone handles other sizes, e.g. full-image baseline mode)
        hp = (tiles - _box3x3(tiles)).reshape(b, l * l, 1, 3)
        corr = (hp.astype(cdt) * packed["corr"][None]
                ).astype(jnp.float32).sum(axis=(1, 3))
        logits = logits + corr * packed["corr_scale"]
    return logits, g


def extractor_forward_packed(packed, tiles):
    """tiles (b, l, l, 3) on packed params -> (b, n_bits) f32 logits —
    the embed-free view of :func:`extractor_forward_packed_embed` (same
    ops, same order; the GAP vector is simply not returned)."""
    return extractor_forward_packed_embed(packed, tiles)[0]


def extractor_forward(params, tiles):
    """tiles (b, l, l, 3) in [-1, 1] -> bit logits (b, n_bits).

    Same math as the original conv formulation (semantic oracle:
    ``kernels.ref.fused_extractor_ref``), expressed through the shared
    matmul body so the fused fp32 kernel is bit-identical to this
    unfused path by construction.  Packing inside jit is free (reshapes
    and casts constant-fold)."""
    return extractor_forward_packed(pack_params(params), tiles)


def extractor_forward_embed(params, tiles):
    """Unfused forward returning (logits, gap_embedding) — the
    embed-emitting decode for pipelines running without the fused
    kernel (``fused_decode=False``).  Logits are bitwise identical to
    :func:`extractor_forward` (same body, same op order)."""
    return extractor_forward_packed_embed(pack_params(params), tiles)


# ---------------------------------------------------------------------------
# encoder H_E
# ---------------------------------------------------------------------------


def init_encoder(key, *, n_bits: int, channels: int = 32,
                 depth: int = 4, tile: int = 0) -> dict:
    ks = jax.random.split(key, depth + 3)
    blocks = []
    cin = 3
    for i in range(depth):
        blocks.append({"w": conv_init(ks[i], 3, 3, cin, channels),
                       "b": jnp.zeros((channels,))})
        cin = channels
    p = {
        "blocks": blocks,
        # input: features + broadcast message + original image
        "fuse": {"w": conv_init(ks[depth], 3, 3, channels + n_bits + 3,
                                channels),
                 "b": jnp.zeros((channels,))},
        "out": {"w": conv_init(ks[depth + 1], 1, 1, channels, 3,
                               scale=0.02),
                "b": jnp.zeros((3,))},
    }
    if tile:
        p["patterns"] = pattern_bank(ks[depth + 2], n_bits, tile)
    return p


def encoder_forward(params, tiles, messages, *, alpha: float = 1.0,
                    embed_rms: float = 0.06):
    """tiles (b, l, l, 3), messages (b, n) in {0,1} -> watermarked tiles.

    The residual is power-normalised to ``embed_rms`` per sample before
    the alpha scale, which (a) pins the embedding strength / PSNR by
    construction (rms 0.06 on a [-1,1] range ~= 30.5 dB) and (b) makes
    training insensitive to the initial scale of the output conv — the
    optimisation then shapes the *code*, not the amplitude."""
    b, l, _, _ = tiles.shape
    x = tiles
    for blk in params["blocks"]:
        x = _block(blk, x)
    m = (2.0 * messages.astype(jnp.float32) - 1.0)
    mb = jnp.broadcast_to(m[:, None, None, :], (b, l, l, m.shape[-1]))
    x = jnp.concatenate([x, mb, tiles], axis=-1)
    x = _block(params["fuse"], x)
    delta = conv2d(x, params["out"]["w"]) + params["out"]["b"]
    if "patterns" in params:
        # spread-spectrum pathway: delta += sum_i mtilde_i * P_i
        delta = delta + jnp.einsum("bn,nhwc->bhwc", m, params["patterns"])
    rms = jnp.sqrt(jnp.mean(jnp.square(delta), axis=(1, 2, 3),
                            keepdims=True) + 1e-8)
    delta = delta * (embed_rms / rms)
    return jnp.clip(tiles + alpha * delta, -1.0, 1.0), delta
