"""HiDDeN-style watermark encoder H_E and tile extractor H_D (QRMark §4.1).

Pure-JAX conv nets (NHWC).  The encoder embeds an N-bit message into an
l x l tile as a residual (x_w = x_0 + alpha * delta, ReDMark-style); the
extractor recovers soft bit logits from a (possibly transformed) tile.
Both are small enough to train on CPU at reduced scale and are the
"decode" stage of the detection pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def conv_init(key, kh, kw, cin, cout, scale=None):
    scale = scale or (2.0 / (kh * kw * cin)) ** 0.5
    return scale * jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)


def conv2d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def channel_norm(x, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def _block(params, x):
    x = conv2d(x, params["w"]) + params["b"]
    return jax.nn.relu(channel_norm(x))


# ---------------------------------------------------------------------------
# extractor H_D
# ---------------------------------------------------------------------------


def init_extractor(key, *, n_bits: int, channels: int = 64,
                   depth: int = 7, tile: int = 0,
                   patterns: "jnp.ndarray" = None) -> dict:
    """HiDDeN-style conv extractor + a spread-spectrum correlation path.

    The correlation bank (init tied to the encoder's pattern bank when
    given) makes the 60-bit code linearly decodable from step 0; the conv
    stack learns the nonlinear robustness corrections under attacks.
    This warm-start is the CPU-scale adaptation recorded in DESIGN.md —
    at paper scale the conv path alone trains to the same point."""
    ks = jax.random.split(key, depth + 4)
    blocks = []
    cin = 3
    for i in range(depth):
        blocks.append({"w": conv_init(ks[i], 3, 3, cin, channels),
                       "b": jnp.zeros((channels,))})
        cin = channels
    p = {
        "blocks": blocks,
        "to_bits": {"w": conv_init(ks[depth], 3, 3, channels, n_bits),
                    "b": jnp.zeros((n_bits,))},
        "head": {"w": dense_init(ks[depth + 1], (n_bits, n_bits),
                                 scale=0.2),
                 "b": jnp.zeros((n_bits,))},
    }
    if tile:
        if patterns is None:
            patterns = pattern_bank(ks[depth + 2], n_bits, tile)
        p["corr"] = patterns
        p["corr_scale"] = jnp.ones((n_bits,))
    return p


def pattern_bank(key, n_bits: int, tile: int):
    """Unit-norm white patterns, one per bit."""
    P = jax.random.normal(key, (n_bits, tile, tile, 3), jnp.float32)
    P = P - P.mean(axis=(1, 2, 3), keepdims=True)
    return P / jnp.sqrt(jnp.sum(jnp.square(P), axis=(1, 2, 3),
                                keepdims=True))


def highpass(x):
    """Remove local mean (3x3): image content is low-frequency, the
    spread-spectrum watermark is white — classic correlation denoising."""
    c = x.shape[-1]
    k = jnp.ones((3, 3, 1, 1), jnp.float32) / 9.0
    k = jnp.tile(k, (1, 1, 1, c))
    blur = jax.lax.conv_general_dilated(
        x, k, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)
    return x - blur


def extractor_forward(params, tiles):
    """tiles (b, l, l, 3) in [-1, 1] -> bit logits (b, n_bits)."""
    x = tiles
    for blk in params["blocks"]:
        x = _block(blk, x)
    x = conv2d(x, params["to_bits"]["w"]) + params["to_bits"]["b"]
    x = x.mean(axis=(1, 2))  # GAP
    logits = x @ params["head"]["w"] + params["head"]["b"]
    if "corr" in params and tiles.shape[1:3] == params["corr"].shape[1:3]:
        # correlation path only at the bank's native tile size (the conv
        # path alone handles other sizes, e.g. full-image baseline mode)
        hp = highpass(tiles)
        corr = jnp.einsum("bhwc,nhwc->bn", hp, params["corr"])
        logits = logits + corr * params["corr_scale"]
    return logits


# ---------------------------------------------------------------------------
# encoder H_E
# ---------------------------------------------------------------------------


def init_encoder(key, *, n_bits: int, channels: int = 32,
                 depth: int = 4, tile: int = 0) -> dict:
    ks = jax.random.split(key, depth + 3)
    blocks = []
    cin = 3
    for i in range(depth):
        blocks.append({"w": conv_init(ks[i], 3, 3, cin, channels),
                       "b": jnp.zeros((channels,))})
        cin = channels
    p = {
        "blocks": blocks,
        # input: features + broadcast message + original image
        "fuse": {"w": conv_init(ks[depth], 3, 3, channels + n_bits + 3,
                                channels),
                 "b": jnp.zeros((channels,))},
        "out": {"w": conv_init(ks[depth + 1], 1, 1, channels, 3,
                               scale=0.02),
                "b": jnp.zeros((3,))},
    }
    if tile:
        p["patterns"] = pattern_bank(ks[depth + 2], n_bits, tile)
    return p


def encoder_forward(params, tiles, messages, *, alpha: float = 1.0,
                    embed_rms: float = 0.06):
    """tiles (b, l, l, 3), messages (b, n) in {0,1} -> watermarked tiles.

    The residual is power-normalised to ``embed_rms`` per sample before
    the alpha scale, which (a) pins the embedding strength / PSNR by
    construction (rms 0.06 on a [-1,1] range ~= 30.5 dB) and (b) makes
    training insensitive to the initial scale of the output conv — the
    optimisation then shapes the *code*, not the amplitude."""
    b, l, _, _ = tiles.shape
    x = tiles
    for blk in params["blocks"]:
        x = _block(blk, x)
    m = (2.0 * messages.astype(jnp.float32) - 1.0)
    mb = jnp.broadcast_to(m[:, None, None, :], (b, l, l, m.shape[-1]))
    x = jnp.concatenate([x, mb, tiles], axis=-1)
    x = _block(params["fuse"], x)
    delta = conv2d(x, params["out"]["w"]) + params["out"]["b"]
    if "patterns" in params:
        # spread-spectrum pathway: delta += sum_i mtilde_i * P_i
        delta = delta + jnp.einsum("bn,nhwc->bhwc", m, params["patterns"])
    rms = jnp.sqrt(jnp.mean(jnp.square(delta), axis=(1, 2, 3),
                            keepdims=True) + 1e-8)
    delta = delta * (embed_rms / rms)
    return jnp.clip(tiles + alpha * delta, -1.0, 1.0), delta
