"""Data pipelines: synthetic procedural images for watermark training /
detection benchmarks, and a sharded token stream for LM training.

Both pipelines are deterministic given (seed, index) so every data-
parallel worker can slice its own shard without coordination — the
property a 1000-node input pipeline needs (no central dataloader), and
what makes checkpoint/restart exactly reproducible (the stream is
indexed by global step).  Host-side prep overlaps device compute via
``repro.core.interleave``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# procedural image corpus (stand-in for MS-COCO in this offline container)
# ---------------------------------------------------------------------------


def synth_image(idx: int, size: int = 256, seed: int = 0) -> np.ndarray:
    """Deterministic procedural RGB image (uint8 HWC): mixed gradients,
    sinusoids and rectangles — enough texture for watermark training."""
    rng = np.random.default_rng(np.uint64(seed) * 1_000_003 + np.uint64(idx))
    yy, xx = np.meshgrid(np.linspace(0, 1, size), np.linspace(0, 1, size),
                         indexing="ij")
    img = np.zeros((size, size, 3), np.float32)
    for c in range(3):
        a, b, ph = rng.uniform(1, 6, 3)
        img[..., c] = 0.5 + 0.25 * np.sin(2 * np.pi * (a * yy + b * xx) + ph)
    # random soft rectangles
    for _ in range(6):
        y0, x0 = rng.integers(0, max(size - 8, 1), 2)
        h, w = rng.integers(min(8, size // 4 + 1), max(size // 2, 9), 2)
        col = rng.uniform(0, 1, 3)
        alpha = rng.uniform(0.2, 0.7)
        img[y0:y0 + h, x0:x0 + w] = (1 - alpha) * img[y0:y0 + h, x0:x0 + w] \
            + alpha * col
    noise = rng.normal(0, 0.02, img.shape)
    return np.clip((img + noise) * 255, 0, 255).astype(np.uint8)


def image_batches(n_images: int, batch: int, *, size: int = 256,
                  seed: int = 0, start: int = 0) -> Iterator[np.ndarray]:
    for b0 in range(start, start + n_images, batch):
        n = min(batch, start + n_images - b0)
        yield np.stack([synth_image(b0 + i, size, seed) for i in range(n)])


@dataclasses.dataclass
class ImageShard:
    """Deterministic per-worker slice of the image stream."""
    worker: int
    n_workers: int
    batch: int
    size: int = 256
    seed: int = 0

    def batches(self, n_batches: int, epoch: int = 0):
        base = epoch * 1_000_000_000 + self.worker
        for k in range(n_batches):
            idx0 = base + k * self.n_workers * self.batch
            yield np.stack([synth_image(idx0 + i * self.n_workers,
                                        self.size, self.seed)
                            for i in range(self.batch)])


# ---------------------------------------------------------------------------
# synthetic token stream for LM training
# ---------------------------------------------------------------------------


def token_batch(step: int, batch: int, seq: int, vocab: int,
                seed: int = 0) -> np.ndarray:
    """Markov-ish synthetic tokens: deterministic in (seed, step)."""
    rng = np.random.default_rng(np.uint64(seed) * 7_919 + np.uint64(step))
    # low-entropy structure so the loss actually decreases
    base = rng.integers(0, vocab, (batch, 1 + seq // 8))
    toks = np.repeat(base, 8, axis=1)[:, :seq]
    noise = rng.integers(0, vocab, toks.shape)
    mask = rng.random(toks.shape) < 0.15
    return np.where(mask, noise, toks).astype(np.int32)


def lm_batches(cfg, shape, *, n_steps: int, seed: int = 0,
               start_step: int = 0):
    """Batches matching lm.input_specs (train mode) for an arch config."""
    b, s = shape.global_batch, shape.seq_len
    for step in range(start_step, start_step + n_steps):
        if cfg.is_encoder_decoder:
            rng = np.random.default_rng(seed * 31 + step)
            tgt = max(64, s // 8)
            yield {"frame_embeds": rng.normal(
                0, 1, (b, s, cfg.d_model)).astype(np.float32),
                "tgt_tokens": token_batch(step, b, tgt, cfg.vocab, seed)}
        elif cfg.frontend == "vision":
            rng = np.random.default_rng(seed * 37 + step)
            nf = cfg.n_frontend_tokens
            yield {"tokens": token_batch(step, b, s - nf, cfg.vocab, seed),
                   "patch_embeds": rng.normal(
                       0, 1, (b, nf, cfg.d_model)).astype(np.float32)}
        else:
            yield {"tokens": token_batch(step, b, s, cfg.vocab, seed)}
