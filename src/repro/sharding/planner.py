"""Shape-aware sharding planner.

Maps every tensor in (params, optimizer state, batch, decode state) to a
``PartitionSpec`` for a given mesh, with divisibility-checked fallbacks:

* **TP** — weight matrices shard their head/ff/vocab-sized dim on ``model``.
* **DP** — batch dims shard on ``(pod, data)`` when divisible.
* **FSDP** — for models whose fp32 master would not fit replicated on the
  data axis, weights additionally shard a d_model-sized dim on the data
  axes (ZeRO-3 style; pjit inserts the per-group all-gathers inside the
  layer scan).
* **ZeRO-1** — optimizer moments always shard on the data axes when the
  corresponding weight does not.
* Decode caches shard batch on data, kv-heads (or head_dim) on ``model``.

Everything degrades to replication when a dim is not divisible — the
dry-run must compile for every (arch × shape × mesh) cell.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    data_axes: tuple      # e.g. ("pod", "data") or ("data",)
    model_axis: str       # "model"
    fsdp: bool            # shard weights on data axes too
    n_micro: int          # gradient-accumulation microbatches (train)
    # §Perf hillclimb levers (serving):
    cache_seq_model: bool = False   # shard decode KV-cache seq on model
    decode_batch_shard: bool = True  # shard decode tokens batch on data

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.data_axes]))

    @property
    def model_size(self) -> int:
        return int(self.mesh.shape[self.model_axis])

    @property
    def n_chips(self) -> int:
        return self.data_size * self.model_size


def make_plan(cfg, shape, mesh, *, act_budget_bytes=1.0e9,
              param_budget_bytes=2.0e9, n_micro=None, fsdp=None,
              cache_seq_model=False, decode_batch_shard=True) -> MeshPlan:
    axes = tuple(mesh.axis_names)
    data_axes = tuple(a for a in axes if a != "model")
    model_axis = "model"
    msize = int(mesh.shape[model_axis])
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    n_chips = msize * dsize

    total_params = cfg.param_counts()["total"]
    if fsdp is None:
        fsdp = (total_params * 4 / msize) > param_budget_bytes

    if n_micro is None:
        n_micro = 1
        if shape.mode == "train":
            ng = cfg.n_layers
            carry_bytes = ng * shape.tokens * cfg.d_model * 2  # bf16 residuals
            while (carry_bytes / n_micro / n_chips > act_budget_bytes
                   and n_micro < shape.global_batch
                   and shape.global_batch % (n_micro * 2) == 0):
                n_micro *= 2
    return MeshPlan(mesh=mesh, data_axes=data_axes, model_axis=model_axis,
                    fsdp=fsdp, n_micro=n_micro,
                    cache_seq_model=cache_seq_model,
                    decode_batch_shard=decode_batch_shard)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _div(n, k):
    return k > 0 and n % k == 0


def _shard_dim(spec_list, dim, size, axes, mesh):
    """Try to assign ``axes`` (tuple) to dim if divisible; returns bool."""
    ax_prod = int(np.prod([mesh.shape[a] for a in axes]))
    if _div(size, ax_prod) and spec_list[dim] is None:
        spec_list[dim] = axes if len(axes) > 1 else axes[0]
        return True
    return False


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def param_specs(cfg, abstract, plan: MeshPlan):
    """PartitionSpec pytree matching ``abstract`` (from lm.abstract_params).

    Rule selection is by tree path (parameter name) + shape divisibility.
    """
    mesh = plan.mesh
    m = plan.model_axis
    d_axes = plan.data_axes

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) or str(p)
                 for p in path]
        name = names[-1]
        stacked = any(n in ("groups", "encoder") for n in names)
        nd = len(leaf.shape)
        off = 1 if stacked else 0  # leading layer-stack axis never sharded
        s = [None] * nd

        def dims():
            return leaf.shape[off:]

        if name in ("ln1", "ln2", "ln_cross", "final_norm", "enc_norm",
                    "norm", "A_log", "D", "dt_bias"):
            pass  # replicate small vectors
        elif name == "embed":
            _shard_dim(s, 0, leaf.shape[0], (m,), mesh)
            if plan.fsdp:
                _shard_dim(s, 1, leaf.shape[1], d_axes, mesh)
        elif name == "head":
            _shard_dim(s, 1, leaf.shape[1], (m,), mesh)
            if plan.fsdp:
                _shard_dim(s, 0, leaf.shape[0], d_axes, mesh)
        elif name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj"):
            if nd - off == 3:  # MoE expert-stacked (E, d, ff)
                if not _shard_dim(s, off, leaf.shape[off], (m,), mesh):
                    _shard_dim(s, off + 2, leaf.shape[off + 2], (m,), mesh)
                if plan.fsdp:
                    _shard_dim(s, off + 1, leaf.shape[off + 1], d_axes, mesh)
            else:
                _shard_dim(s, off + 1, leaf.shape[off + 1], (m,), mesh)
                if plan.fsdp:
                    _shard_dim(s, off, leaf.shape[off], d_axes, mesh)
        elif name in ("wo", "w_down", "out_proj"):
            if nd - off == 3:  # (E, ff, d)
                if not _shard_dim(s, off, leaf.shape[off], (m,), mesh):
                    _shard_dim(s, off + 1, leaf.shape[off + 1], (m,), mesh)
                if plan.fsdp:
                    _shard_dim(s, off + 2, leaf.shape[off + 2], d_axes, mesh)
            else:
                _shard_dim(s, off, leaf.shape[off], (m,), mesh)
                if plan.fsdp:
                    _shard_dim(s, off + 1, leaf.shape[off + 1], d_axes, mesh)
        elif name == "router":
            pass  # replicate (d, E): small, read by every token
        elif name == "conv_w":
            _shard_dim(s, off + 1, leaf.shape[off + 1], (m,), mesh)
        else:
            pass
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec_for, abstract)


def opt_specs(cfg, abstract_params, plan: MeshPlan):
    """Adam moments: like params, plus ZeRO-1 data-sharding when possible."""
    pspecs = param_specs(cfg, abstract_params, plan)
    mesh = plan.mesh

    def zero1(leaf, spec):
        s = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = []
        for e in s:
            if isinstance(e, tuple):
                used.extend(e)
            elif e is not None:
                used.append(e)
        if any(a in used for a in plan.data_axes):
            return P(*s)  # already data-sharded (FSDP)
        # shard the largest unsharded dim over the data axes
        order = sorted(range(len(leaf.shape)), key=lambda i: -leaf.shape[i])
        for i in order:
            if s[i] is None and _shard_dim(s, i, leaf.shape[i],
                                           plan.data_axes, mesh):
                break
        return P(*s)

    return jax.tree_util.tree_map(zero1, abstract_params, pspecs)


# ---------------------------------------------------------------------------
# batch / activation / decode-state specs
# ---------------------------------------------------------------------------


def batch_specs(cfg, shape, plan: MeshPlan, batch_abstract):
    """Input batch: shard the leading batch dim over the data axes."""
    mesh = plan.mesh

    def spec_for(leaf):
        s = [None] * len(leaf.shape)
        _shard_dim(s, 0, leaf.shape[0], plan.data_axes, mesh)
        return P(*s)

    return jax.tree.map(spec_for, batch_abstract)


def decode_state_specs(cfg, plan: MeshPlan, state_abstract):
    """Decode caches: (ng, b, S, kvh, hd) and SSM states."""
    mesh = plan.mesh
    m = plan.model_axis

    def spec_for(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", None)) or str(p)
                 for p in path]
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        s = [None] * nd
        if nd == 0:
            return P()
        if name == "pos":  # (ng, b, S)
            if plan.decode_batch_shard:
                _shard_dim(s, 1, leaf.shape[1], plan.data_axes, mesh)
            if plan.cache_seq_model:
                _shard_dim(s, 2, leaf.shape[2], (m,), mesh)
            return P(*s)
        if name in ("k", "v") or (nd == 5 and name not in ("state",)):
            # (ng, b, S, kvh, hd) attn cache or cross-kv tuple leaf
            if plan.decode_batch_shard:
                _shard_dim(s, 1, leaf.shape[1], plan.data_axes, mesh)
            if plan.cache_seq_model:
                # flash-decode style: split the cache length over model;
                # softmax max/sum become tiny cross-shard reductions
                _shard_dim(s, 2, leaf.shape[2], (m,), mesh)
            elif not _shard_dim(s, 3, leaf.shape[3], (m,), mesh):
                _shard_dim(s, 4, leaf.shape[4], (m,), mesh)
            return P(*s)
        if name == "state":  # (ng, b, g, hg, p, n)
            if plan.decode_batch_shard:
                _shard_dim(s, 1, leaf.shape[1], plan.data_axes, mesh)
            _shard_dim(s, 3, leaf.shape[3], (m,), mesh)
            return P(*s)
        if name == "conv":  # (ng, b, cw-1, conv_dim)
            if plan.decode_batch_shard:
                _shard_dim(s, 1, leaf.shape[1], plan.data_axes, mesh)
            _shard_dim(s, 3, leaf.shape[3], (m,), mesh)
            return P(*s)
        if nd >= 2:
            _shard_dim(s, 1 if nd > 2 else 0, leaf.shape[1 if nd > 2 else 0],
                       plan.data_axes, mesh)
        return P(*s)

    return jax.tree_util.tree_map_with_path(spec_for, state_abstract)


def detection_batch_spec(ndim: int) -> P:
    """Detection image batch: leading batch dim on ``data``, spatial and
    channel dims replicated (each image is decoded whole on one device)."""
    return P("data", *([None] * (ndim - 1)))


def shard_detection_batch(mesh, batch):
    """Place a (padded, data-axis-divisible) detection batch on the 1-D
    detection mesh.  Params/keys stay replicated; jit propagates the
    batch sharding through preprocess/tile/decode, which are all
    per-image, so no cross-device collectives appear in the graph."""
    return jax.device_put(
        batch, NamedSharding(mesh, detection_batch_spec(np.ndim(batch))))


def to_shardings(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
