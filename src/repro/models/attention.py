"""Attention: GQA with optional sliding window, blocked (flash-style)
softmax for long sequences, and KV-cache decode.

Two execution paths:
  * ``blocked_attention`` — online-softmax over KV blocks via ``lax.scan``;
    memory O(s * kv_block) instead of O(s^2).  Used for train/prefill.
    ``causal_skip`` drops KV blocks strictly above the diagonal per Q block
    (halves attention FLOPs; this is one of the §Perf hillclimb levers).
  * ``decode_attention`` — single-token query against a cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# blocked flash-style attention (pure JAX)
# ---------------------------------------------------------------------------


def blocked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      kv_len=None, q_block=512, kv_block=512,
                      causal_skip=True):
    """q: (b, sq, h, hd); k/v: (b, skv, kvh, hd).  GQA via head grouping.

    ``q_offset``: absolute position of q[0] (for prefill continuation).
    ``kv_len``: number of valid kv entries (scalar or None = all).
    ``causal_skip``: statically skip fully-masked KV blocks (upper
    triangle).  Grid is (nq, nkv) lower-triangular when causal.
    """
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    rep = h // kvh
    scale = hd ** -0.5

    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    # pad to block multiples
    sq_p = -(-sq // q_block) * q_block
    skv_p = -(-skv // kv_block) * kv_block
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    nq, nkv = sq_p // q_block, skv_p // kv_block

    # (b, nq, qb, kvh, rep, hd)
    qb = qp.reshape(b, nq, q_block, kvh, rep, hd)
    kb = kp.reshape(b, nkv, kv_block, kvh, hd)
    vb = vp.reshape(b, nkv, kv_block, kvh, hd)

    q_pos = q_offset + jnp.arange(sq_p).reshape(nq, q_block)
    kv_pos = jnp.arange(skv_p).reshape(nkv, kv_block)
    valid_kv = skv if kv_len is None else kv_len

    def q_block_fn(qi, qblk, qpos):
        # qblk: (b, qb, kvh, rep, hd); qpos: (qb,)
        m0 = jnp.full((b, q_block, kvh, rep), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_block, kvh, rep), jnp.float32)
        a0 = jnp.zeros((b, q_block, kvh, rep, hd), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kblk, vblk, kpos = inp
            # matmuls stay in the storage dtype with f32 ACCUMULATION
            # (preferred_element_type) — upcasting K/V first materialises
            # f32 copies of the whole cache (§Perf hillclimb, cell B it.3)
            s = jnp.einsum("bqgrd,bkgd->bqgrk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < valid_kv
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqgrk,bkgd->bqgrd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        if causal and causal_skip:
            # only KV blocks whose start can be <= this q block's end
            hi = min(nkv, int((qi + 1) * q_block + kv_block - 1) // kv_block)
            hi = max(hi, 1)
        else:
            hi = nkv
        xs = (kb[:, :hi].swapaxes(0, 1), vb[:, :hi].swapaxes(0, 1),
              kv_pos[:hi])
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    outs = []
    for qi in range(nq):  # static python loop: per-block kv bounds differ
        outs.append(q_block_fn(qi, qb[:, qi], q_pos[qi]))
    out = jnp.stack(outs, axis=1)  # (b, nq, qb, kvh, rep, hd)
    out = out.reshape(b, sq_p, h, hd)[:, :sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, cache_len, window=0,
                     positions=None):
    """One-step decode.  q: (b, 1, h, hd); caches: (b, S, kvh, hd).

    ``cache_len``: number of valid entries (traced scalar ok).
    ``positions``: absolute position of each cache slot (b, S) for ring
    buffers (SWA); None means slot i holds position i.
    """
    b, _, h, hd = q.shape
    _, S, kvh, _ = k_cache.shape
    rep = h // kvh
    scale = hd ** -0.5
    # storage-dtype matmul + f32 accumulation: never materialise an f32
    # copy of the cache (it dominated decode HBM bytes — §Perf cell B)
    qf = q.reshape(b, kvh, rep, hd).astype(k_cache.dtype)
    s = jnp.einsum("bgrd,bkgd->bgrk", qf, k_cache,
                   preferred_element_type=jnp.float32) * scale
    slot = jnp.arange(S)
    if positions is None:
        mask = slot[None, :] < cache_len  # (1 or b, S)
    else:
        q_pos = cache_len - 1
        mask = (positions <= q_pos) & (positions >= 0)
        if window:
            mask = mask & (positions > q_pos - window)
    s = jnp.where(mask[:, None, None, :] if mask.ndim == 2
                  else mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def init_attn(key, cfg, dtype=jnp.float32):
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, kvh * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, kvh * hd), dtype=dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype=dtype),
    }


def attn_forward(params, x, cfg, *, mode, cache=None, cache_index=None,
                 positions=None, cross_kv=None, causal=True):
    """Returns (out, new_cache).

    mode: 'train' | 'prefill' | 'decode'.
    cache: {"k": (b,S,kvh,hd), "v": ...} for self-attention decode.
    cross_kv: precomputed (k, v) for cross-attention (enc-dec); rope skipped.
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(b, s, h, hd)
    if cross_kv is not None:
        k, v = cross_kv
        out = blocked_attention(q, k, v, causal=False) if mode != "decode" \
            else decode_attention(q, k, v, cache_len=k.shape[1])
        return (out.reshape(b, s, h * hd) @ params["wo"].astype(dt)), cache

    k = (x @ params["wk"].astype(dt)).reshape(b, s, kvh, hd)
    v = (x @ params["wv"].astype(dt)).reshape(b, s, kvh, hd)
    if positions is None:
        base = 0 if cache_index is None else cache_index
        positions = base + jnp.arange(s)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        S = cache["k"].shape[1]
        if cfg.sliding_window and cfg.sliding_window < S:
            raise ValueError("SWA cache must be <= window")
        slot = (cache_index % S) if cfg.sliding_window else cache_index
        k_cache = cache["k"].at[:, slot].set(k[:, 0])
        v_cache = cache["v"].at[:, slot].set(v[:, 0])
        if cfg.sliding_window:
            # ring buffer: slot i holds position, tracked explicitly
            pos = cache["pos"].at[:, slot].set(positions[:, 0]) \
                if "pos" in cache else None
            out = decode_attention(q, k_cache, v_cache,
                                   cache_len=cache_index + 1,
                                   window=cfg.sliding_window,
                                   positions=pos)
            new_cache = {"k": k_cache, "v": v_cache}
            if pos is not None:
                new_cache["pos"] = pos
        else:
            out = decode_attention(q, k_cache, v_cache,
                                   cache_len=cache_index + 1)
            new_cache = {"k": k_cache, "v": v_cache}
    else:
        out = blocked_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window)
        new_cache = None
        if mode == "prefill":
            new_cache = make_prefill_cache(cfg, k, v, positions)
    out = out.reshape(b, s, h * hd) @ params["wo"].astype(dt)
    return out, new_cache


def make_prefill_cache(cfg, k, v, positions):
    """Turn prefill K/V into a decode cache (ring-compressed for SWA)."""
    b, s, kvh, hd = k.shape
    if cfg.sliding_window and s > cfg.sliding_window:
        W = cfg.sliding_window
        # last W entries land at ring slots (pos % W)
        kw, vw = k[:, -W:], v[:, -W:]
        pw = positions[:, -W:] * jnp.ones((b, 1), jnp.int32)
        slots = pw[0] % W
        kr = jnp.zeros_like(kw).at[:, slots].set(kw)
        vr = jnp.zeros_like(vw).at[:, slots].set(vw)
        pr = jnp.full((b, W), -1, jnp.int32).at[:, slots].set(pw)
        return {"k": kr, "v": vr, "pos": pr}
    cache = {"k": k, "v": v}
    if cfg.sliding_window:
        cache["pos"] = positions * jnp.ones((b, 1), jnp.int32)
    return cache


def empty_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    c = {"k": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
         "v": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype)}
    if cfg.sliding_window:
        c["pos"] = jnp.full((batch, S), -1, jnp.int32)
    return c
