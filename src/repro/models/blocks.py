"""Backbone blocks: pre-norm residual layers, heterogeneous layer groups
for hybrid (jamba-style) interleave, and scan-over-layers assembly.

Layers are organised into *groups*: the smallest repeating pattern of the
architecture (1 layer for uniform archs, ``attn_period`` layers for
hybrids).  Group parameters are stacked on a leading axis so the backbone
is a single ``lax.scan`` — HLO size stays O(1) in depth, which keeps the
512-device dry-run compiles tractable and is how production frameworks
(MaxText et al.) handle 100-layer models.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention, moe as moe_lib, ssm as ssm_lib
from repro.models.layers import init_mlp, rmsnorm, swiglu_mlp, dense_init


def group_size(cfg) -> int:
    g = cfg.attn_period if cfg.attn_period else 1
    if cfg.moe is not None:
        import math
        g = math.lcm(g, cfg.moe.period)
    return g


def n_groups(cfg) -> int:
    gs = group_size(cfg)
    assert cfg.n_layers % gs == 0, (cfg.n_layers, gs)
    return cfg.n_layers // gs


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------


def init_layer(key, cfg, layer_idx_in_group, *, cross=False,
               dtype=jnp.float32):
    """One backbone layer.  ``layer_idx_in_group`` selects kind/moe since
    the pattern is identical across groups."""
    i = layer_idx_in_group
    kind = cfg.layer_kind(i)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "attn":
        p["attn"] = attention.init_attn(k1, cfg, dtype)
    else:
        p["ssm"] = ssm_lib.init_ssm(k1, cfg, dtype)
    if cfg.layer_is_moe(i):
        p["moe"] = moe_lib.init_moe(k2, cfg, cfg.moe, dtype)
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        del p["ln2"]  # pure-SSM block (mamba2): single pre-mixer norm
    if cross:
        p["cross"] = attention.init_attn(k3, cfg, dtype)
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def layer_forward(params, x, cfg, i, *, mode, cache=None, cache_index=None,
                  positions=None, cross_kv=None, causal=True):
    kind = cfg.layer_kind(i)
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    if kind == "attn":
        h, new_cache = attention.attn_forward(
            params["attn"], h, cfg, mode=mode, cache=cache,
            cache_index=cache_index, positions=positions, causal=causal)
    else:
        h, new_cache = ssm_lib.ssm_forward(params["ssm"], h, cfg, mode=mode,
                                           cache=cache)
    x = x + h
    if cross_kv is not None:
        h = rmsnorm(x, params["ln_cross"], cfg.norm_eps)
        h, _ = attention.attn_forward(params["cross"], h, cfg,
                                      mode="train" if mode != "decode"
                                      else "decode",
                                      cross_kv=cross_kv)
        x = x + h
    if "ln2" not in params:  # pure-SSM block: no MLP sub-layer
        return x, new_cache
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    if "moe" in params:
        h = moe_lib.moe_mlp(params["moe"], h, cfg.moe)
    else:
        h = swiglu_mlp(params["mlp"], h)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# layer groups + scan
# ---------------------------------------------------------------------------


def init_group(key, cfg, *, cross=False, dtype=jnp.float32):
    gs = group_size(cfg)
    keys = jax.random.split(key, gs)
    return tuple(init_layer(keys[i], cfg, i, cross=cross, dtype=dtype)
                 for i in range(gs))


def empty_group_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """Cache pytree for one group (entries keyed by in-group position)."""
    caches = []
    for i in range(group_size(cfg)):
        if cfg.layer_kind(i) == "attn":
            caches.append(attention.empty_cache(cfg, batch, max_len, dtype))
        else:
            caches.append(ssm_lib.empty_ssm_cache(cfg, batch, dtype))
    return tuple(caches)


def group_forward(params, x, cfg, *, mode, caches=None, cache_index=None,
                  positions=None, cross_kv=None, causal=True):
    gs = group_size(cfg)
    caches = caches if caches is not None else (None,) * gs
    new_caches = []
    for i in range(gs):
        x, nc = layer_forward(params[i], x, cfg, i, mode=mode,
                              cache=caches[i], cache_index=cache_index,
                              positions=positions, cross_kv=cross_kv,
                              causal=causal)
        new_caches.append(nc)
    return x, tuple(new_caches)


def init_stacked_groups(key, cfg, *, cross=False, dtype=jnp.float32):
    """All backbone groups with leaves stacked on a leading axis."""
    ng = n_groups(cfg)
    keys = jax.random.split(key, ng)
    return jax.vmap(lambda k: init_group(k, cfg, cross=cross, dtype=dtype))(
        keys)


def run_backbone(group_params, x, cfg, *, mode, caches=None,
                 cache_index=None, positions=None, cross_kv_stack=None,
                 causal=True, remat=False, unroll=False):
    """Scan the stacked groups.  ``caches`` leaves have leading ng axis.

    ``unroll=True`` replaces the ``lax.scan`` with a python loop — used by
    the dry-run cost probes (XLA cost_analysis counts a while body once,
    so per-group costs are measured on unrolled depth-1/2 probes).

    Returns (x, new_caches or None).
    """
    want_cache = caches is not None
    if unroll:
        ng = jax.tree.leaves(group_params)[0].shape[0]
        sel = lambda t, i: jax.tree.map(lambda l: l[i], t)
        new_caches = []
        for gi in range(ng):
            x, nc = group_forward(
                sel(group_params, gi), x, cfg, mode=mode,
                caches=sel(caches, gi) if caches is not None else None,
                cache_index=cache_index, positions=positions,
                cross_kv=sel(cross_kv_stack, gi)
                if cross_kv_stack is not None else None, causal=causal)
            new_caches.append(nc)
        if not want_cache:
            return x, None
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new_caches)
        return x, stacked

    def body(carry, inp):
        xc = carry
        gp, gc, ckv = inp
        xo, nc = group_forward(gp, xc, cfg, mode=mode, caches=gc,
                               cache_index=cache_index, positions=positions,
                               cross_kv=ckv, causal=causal)
        return xo, (nc if want_cache else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)

    ng = n_groups(cfg)
    if cross_kv_stack is None:
        ckv_xs = None
    else:
        ckv_xs = cross_kv_stack
    xs = (group_params, caches, ckv_xs)
    # lax.scan tolerates None leaves only via explicit trees; replace None
    # subtrees with per-iteration dummies
    if caches is None and ckv_xs is None:
        def body0(c, gp):
            xo, _ = body(c, (gp, None, None))
            return xo, None
        x, _ = jax.lax.scan(body0, x, group_params)
        return x, None
    if caches is None:
        x, _ = jax.lax.scan(lambda c, i: (body(c, (i[0], None, i[1]))[0],
                                          None), x, (group_params, ckv_xs))
        return x, None
    if ckv_xs is None:
        x, new_caches = jax.lax.scan(
            lambda c, i: body(c, (i[0], i[1], None)), x,
            (group_params, caches))
        return x, new_caches
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches
