"""Mamba-2 (SSD — state-space duality) block, TPU-native matmul form.

The chunked SSD algorithm expresses the selective scan as block matmuls
(MXU-friendly) plus a short ``lax.scan`` over chunk boundary states, which
is the TPU adaptation of the paper's GPU kernel: intra-chunk work is dense
einsum, inter-chunk work is an O(seq/chunk) recurrence.

Shapes: x (b, l, d); internally d_inner = expand*d, heads nh = d_inner/hp,
state n = d_state, groups g (B/C shared per group, heads split g*hg = nh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rmsnorm

NEG_INF = -1e30


def init_ssm(key, cfg, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    nh = di // s.head_dim
    conv_dim = di + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 5)
    A = jax.random.uniform(ks[2], (nh,), jnp.float32, 1.0, 16.0)
    dt = jnp.exp(jax.random.uniform(ks[3], (nh,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * s.n_groups * s.d_state
                                      + nh), dtype=dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), scale=0.1,
                             dtype=dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "dt_bias": dt_bias.astype(dtype),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _segsum(x):
    """x: (..., T) -> (..., T, T); out[i, j] = sum_{j < k <= i} x[k]."""
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    T = x.shape[-1]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, ss, NEG_INF)


def _split(params, x, cfg):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    gn = s.n_groups * s.d_state
    nh = di // s.head_dim
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * gn], axis=-1)
    return z, xBC, dt, di, gn, nh


def ssd_chunked(x, dt, A, B, C, chunk, initial_state=None):
    """Chunked SSD scan.

    x: (b, l, g, hg, p) [dt-weighted NOT applied yet]; dt: (b, l, h);
    A: (h,) negative reals; B, C: (b, l, g, n).
    Returns y (b, l, g, hg, p) and final state (b, g, hg, p, n).
    """
    b, l, g, hg, p = x.shape
    n = B.shape[-1]
    h = g * hg
    cl = min(chunk, l)
    nc = l // cl
    assert l % cl == 0, f"seq {l} not divisible by chunk {cl}"

    xc = x.reshape(b, nc, cl, g, hg, p)
    dtc = dt.reshape(b, nc, cl, g, hg)
    Bc = B.reshape(b, nc, cl, g, n)
    Cc = C.reshape(b, nc, cl, g, n)
    dA = dtc * A.reshape(g, hg)  # (b,nc,cl,g,hg)
    dA_cs = jnp.cumsum(dA, axis=2)
    xdt = xc * dtc[..., None]

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, -1)))  # (b,nc,g,hg,cl,cl)
    scores = jnp.einsum("bclgn,bcsgn->bcgls", Cc, Bc,
                        preferred_element_type=jnp.float32)
    att = scores[:, :, :, None] * L  # (b,nc,g,hg,cl,cl)
    y = jnp.einsum("bcghls,bcsghp->bclghp", att, xdt,
                   preferred_element_type=jnp.float32)

    # 2) per-chunk contribution to boundary states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :, :] - dA_cs)  # (b,nc,cl,g,hg)
    states = jnp.einsum("bcsgn,bcsgh,bcsghp->bcghpn", Bc, decay_states, xdt,
                        preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence over boundary states
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :, :])  # (b,nc,g,hg)
    s0 = jnp.zeros((b, g, hg, p, n), jnp.float32) if initial_state is None \
        else initial_state.astype(jnp.float32)

    def step(S, inp):
        dec, st = inp
        S_new = S * dec[..., None, None] + st
        return S_new, S  # emit the *previous* state for this chunk

    xs = (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0))
    final_state, prev_states = jax.lax.scan(step, s0, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,g,hg,p,n)

    # 4) contribution of the carried-in state to each position
    out_decay = jnp.exp(dA_cs)  # (b,nc,cl,g,hg)
    y_off = jnp.einsum("bclgn,bcghpn,bclgh->bclghp", Cc, prev_states,
                       out_decay, preferred_element_type=jnp.float32)
    y = (y + y_off).reshape(b, l, g, hg, p)
    return y, final_state


def _causal_conv(xBC, w):
    """Depthwise causal conv, width cw.  xBC: (b, l, c); w: (cw, c)."""
    cw = w.shape[0]
    out = jnp.zeros_like(xBC)
    for i in range(cw):  # cw == 4: unrolled shifts beat conv_general here
        shift = cw - 1 - i
        xs = jnp.pad(xBC, ((0, 0), (shift, 0), (0, 0)))[:, :xBC.shape[1]]
        out = out + xs * w[i].astype(xBC.dtype)
    return out


def ssm_forward(params, x, cfg, *, mode, cache=None):
    """Mamba-2 block.  x: (b, l, d) -> (b, l, d).  Returns (y, new_cache).

    cache (decode): {"conv": (b, cw-1, conv_dim), "state": (b,g,hg,p,n)}.
    """
    s = cfg.ssm
    b, l, d = x.shape
    dt_ = x.dtype
    z, xBC, dt, di, gn, nh = _split(params, x, cfg)
    g, hp = s.n_groups, s.head_dim
    hg = nh // g
    n = s.d_state
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    if mode == "decode":
        window = jnp.concatenate([cache["conv"].astype(dt_), xBC], axis=1)
        conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                              params["conv_w"].astype(jnp.float32))
        xBC_t = jax.nn.silu(conv_out).astype(dt_)  # (b, conv_dim)
        xs, B, C = jnp.split(xBC_t, [di, di + gn], axis=-1)
        xh = xs.reshape(b, g, hg, hp)
        B = B.reshape(b, g, n)
        C = C.reshape(b, g, n)
        dt1 = dt[:, 0].reshape(b, g, hg)
        dA = jnp.exp(dt1 * A.reshape(g, hg))  # (b,g,hg)
        S = cache["state"].astype(jnp.float32)
        S = S * dA[..., None, None] + jnp.einsum(
            "bghp,bgn,bgh->bghpn", xh.astype(jnp.float32), B, dt1)
        y = jnp.einsum("bghpn,bgn->bghp", S, C)
        y = y + xh.astype(jnp.float32) * params["D"].astype(
            jnp.float32).reshape(g, hg)[..., None]
        y = y.reshape(b, 1, di).astype(dt_)
        new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype),
                     "state": S.astype(cache["state"].dtype)}
    else:
        xBC = jax.nn.silu(_causal_conv(xBC, params["conv_w"]))
        # pad seq to a chunk multiple; padded steps get dt=0 (no decay, no
        # contribution) so the final state is exact
        cl = min(s.chunk, l)
        lp = -(-l // cl) * cl
        if lp != l:
            xBC = jnp.pad(xBC, ((0, 0), (0, lp - l), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, lp - l), (0, 0)))
            dt = dt * (jnp.arange(lp) < l)[None, :, None]
        xs, B, C = jnp.split(xBC, [di, di + gn], axis=-1)
        xh = xs.reshape(b, lp, g, hg, hp)
        B = B.reshape(b, lp, g, n).astype(jnp.float32)
        C = C.reshape(b, lp, g, n).astype(jnp.float32)
        dth = dt.reshape(b, lp, g, hg)
        y, final = ssd_chunked(xh.astype(jnp.float32), dth, A, B, C, s.chunk)
        y = y[:, :l] + xh.astype(jnp.float32)[:, :l] * params["D"].astype(
            jnp.float32).reshape(g, hg)[..., None]
        y = y.reshape(b, l, di).astype(dt_)
        new_cache = None
        if mode == "prefill":
            conv_tail = _prefill_conv_tail(params, x, cfg)
            new_cache = {"conv": conv_tail.astype(jnp.bfloat16),
                         "state": final.astype(jnp.bfloat16)}

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, params["norm"], cfg.norm_eps)
    return y @ params["out_proj"].astype(dt_), new_cache


def _prefill_conv_tail(params, x, cfg):
    """Last cw-1 pre-conv activations, for seeding the decode conv cache."""
    s = cfg.ssm
    z, xBC, dt, di, gn, nh = _split(params, x, cfg)
    return xBC[:, -(s.conv_width - 1):]


def empty_ssm_cache(cfg, batch, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    g = s.n_groups
    conv_dim = di + 2 * g * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, g, nh // g, s.head_dim, s.d_state), dtype),
    }
