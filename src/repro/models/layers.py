"""Shared neural-net layers (pure functional JAX, params = pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Init = jax.nn.initializers


def dense_init(key, shape, scale=0.02, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


def rmsnorm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def swiglu_mlp(params, x):
    """SwiGLU MLP.  params: w_gate (d,ff), w_up (d,ff), w_down (ff,d)."""
    dt = x.dtype
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)


def init_mlp(key, d, ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), dtype=dtype),
        "w_up": dense_init(k2, (d, ff), dtype=dtype),
        "w_down": dense_init(k3, (ff, d), dtype=dtype),
    }


# -- rotary position embeddings --------------------------------------------


def rope_freqs(head_dim, theta):
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x, positions, theta):
    """x: (..., s, h, hd); positions: broadcastable to (..., s)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    sin = jnp.sin(angles)[..., None, :]  # (..., s, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_lm_loss(logits, tokens, mask=None):
    """Next-token cross-entropy.  logits: (b, s, V) predicts tokens[:, 1:]."""
    tgt = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        mask = jnp.ones_like(tgt, dtype=jnp.float32)
    else:
        mask = mask[:, 1:].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
