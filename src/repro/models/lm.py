"""Top-level model assembly: init, train/prefill/decode forwards, and
abstract input specs for the dry-run.

All functions are pure; the same code path serves the 10 assigned
architectures (dense / MoE / SSM / hybrid / VLM / audio enc-dec) driven by
:class:`repro.configs.base.ArchConfig`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention, blocks
from repro.models.layers import causal_lm_loss, dense_init, rmsnorm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg, key, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    d, V = cfg.d_model, cfg.vocab
    p = {"embed": dense_init(ks[0], (V, d), dtype=dtype),
         "final_norm": jnp.zeros((d,), dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (d, V), dtype=dtype)
    if cfg.is_encoder_decoder:
        assert blocks.group_size(cfg) == 1, "enc-dec assumes uniform layers"
        enc_cfg = cfg
        enc_keys = jax.random.split(ks[2], cfg.n_enc_layers)
        p["encoder"] = jax.vmap(
            lambda k: blocks.init_group(k, enc_cfg, dtype=dtype))(enc_keys)
        p["enc_norm"] = jnp.zeros((d,), dtype)
        dec_keys = jax.random.split(ks[3], cfg.n_layers)
        p["groups"] = jax.vmap(
            lambda k: blocks.init_group(k, cfg, cross=True, dtype=dtype))(
                dec_keys)
    else:
        p["groups"] = blocks.init_stacked_groups(ks[2], cfg, dtype=dtype)
    return p


def abstract_params(cfg, dtype=jnp.float32):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    return jax.eval_shape(
        functools.partial(init_params, cfg, dtype=dtype),
        jax.random.key(0))


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg, compute_dtype):
    return params["embed"].astype(compute_dtype)[tokens]


def unembed(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return x @ w.astype(x.dtype)


def _assemble_inputs(params, batch, cfg, compute_dtype):
    """Token/frontend fusion -> (x, loss_mask, tokens_for_loss)."""
    if cfg.frontend == "vision":
        text = batch["tokens"]  # (b, s_text)
        patches = batch["patch_embeds"].astype(compute_dtype)  # (b, nf, d)
        xt = embed_tokens(params, text, cfg, compute_dtype)
        x = jnp.concatenate([patches, xt], axis=1)
        b, nf = patches.shape[:2]
        pad = jnp.zeros((b, nf), dtype=text.dtype)
        tokens_full = jnp.concatenate([pad, text], axis=1)
        mask = jnp.concatenate([jnp.zeros((b, nf), bool),
                                jnp.ones_like(text, bool)], axis=1)
        return x, mask, tokens_full
    tokens = batch["tokens"]
    x = embed_tokens(params, tokens, cfg, compute_dtype)
    return x, jnp.ones_like(tokens, bool), tokens


# ---------------------------------------------------------------------------
# encoder (enc-dec archs)
# ---------------------------------------------------------------------------


def encode(params, frame_embeds, cfg, *, remat=False, unroll=False):
    x = frame_embeds
    x, _ = blocks.run_backbone(params["encoder"], x, cfg, mode="train",
                               causal=False, remat=remat, unroll=unroll)
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def cross_kv_stack(params, enc_out, cfg):
    """Precompute cross-attention K/V for every decoder layer (stacked)."""
    def one(gp):
        cp = gp[0]["cross"]
        b, s, _ = enc_out.shape
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        dt = enc_out.dtype
        k = (enc_out @ cp["wk"].astype(dt)).reshape(b, s, kvh, hd)
        v = (enc_out @ cp["wv"].astype(dt)).reshape(b, s, kvh, hd)
        return (k, v)
    return jax.vmap(one, in_axes=(0,))(params["groups"])


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def forward_train(params, batch, cfg, *, compute_dtype=jnp.bfloat16,
                  remat=True, unroll=False):
    """Returns scalar LM loss for one batch."""
    pc = params
    if cfg.is_encoder_decoder:
        enc_out = encode(pc, batch["frame_embeds"].astype(compute_dtype),
                         cfg, remat=remat, unroll=unroll)
        ckv = cross_kv_stack(pc, enc_out, cfg)
        tgt = batch["tgt_tokens"]
        x = embed_tokens(pc, tgt, cfg, compute_dtype)
        x, _ = blocks.run_backbone(pc["groups"], x, cfg, mode="train",
                                   cross_kv_stack=ckv, remat=remat,
                                   unroll=unroll)
        x = rmsnorm(x, pc["final_norm"], cfg.norm_eps)
        logits = unembed(pc, x, cfg)
        return causal_lm_loss(logits, tgt)
    x, mask, tokens = _assemble_inputs(pc, batch, cfg, compute_dtype)
    x, _ = blocks.run_backbone(pc["groups"], x, cfg, mode="train",
                               remat=remat, unroll=unroll)
    x = rmsnorm(x, pc["final_norm"], cfg.norm_eps)
    logits = unembed(pc, x, cfg)
    return causal_lm_loss(logits, tokens, mask=mask)


def forward_prefill(params, batch, cfg, *, compute_dtype=jnp.bfloat16,
                    unroll=False):
    """Prefill: consume the prompt, return (last_logits, decode_state)."""
    if cfg.is_encoder_decoder:
        enc_out = encode(params, batch["frame_embeds"].astype(compute_dtype),
                         cfg, unroll=unroll)
        ckv = cross_kv_stack(params, enc_out, cfg)
        tgt = batch["tgt_tokens"]
        x = embed_tokens(params, tgt, cfg, compute_dtype)
        x, caches = _prefill_backbone(params, x, cfg, cross_kv_stack_=ckv,
                                      unroll=unroll)
        state = {"caches": caches, "cross": ckv,
                 "index": jnp.int32(tgt.shape[1])}
    else:
        x, _, _ = _assemble_inputs(params, batch, cfg, compute_dtype)
        x, caches = _prefill_backbone(params, x, cfg, unroll=unroll)
        state = {"caches": caches, "index": jnp.int32(x.shape[1])}
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits, state


def _prefill_backbone(params, x, cfg, cross_kv_stack_=None, unroll=False):
    ng = (cfg.n_layers // blocks.group_size(cfg))
    b, s = x.shape[:2]
    proto = blocks.empty_group_cache(cfg, b, s)
    caches = jax.tree.map(
        lambda l: jnp.zeros((ng,) + l.shape, l.dtype), proto)
    x, new_caches = blocks.run_backbone(
        params["groups"], x, cfg, mode="prefill", caches=caches,
        cross_kv_stack=cross_kv_stack_, unroll=unroll)
    return x, new_caches


def forward_decode(params, tokens, state, cfg, *,
                   compute_dtype=jnp.bfloat16, unroll=False):
    """One decode step.  tokens: (b, 1).  Returns (logits, new_state)."""
    x = embed_tokens(params, tokens, cfg, compute_dtype)
    ckv = state.get("cross")
    x, new_caches = blocks.run_backbone(
        params["groups"], x, cfg, mode="decode", caches=state["caches"],
        cache_index=state["index"], cross_kv_stack=ckv, unroll=unroll)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, cfg)
    new_state = dict(state, caches=new_caches, index=state["index"] + 1)
    return logits, new_state


# ---------------------------------------------------------------------------
# abstract inputs for the dry-run (ShapeDtypeStruct only, no allocation)
# ---------------------------------------------------------------------------


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape, *, compute_dtype=jnp.bfloat16):
    """Abstract model inputs for an (arch, shape) cell.

    train/prefill -> {"batch": ...}; decode -> {"tokens", "state"}.
    Shapes follow the assignment: decode shapes are one new token against a
    KV cache of ``seq_len``; [audio]/[vlm] frontends provide precomputed
    embeddings.
    """
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    i32 = jnp.int32
    if shape.mode in ("train", "prefill"):
        if cfg.is_encoder_decoder:
            tgt = max(64, s // 8)
            batch = {"frame_embeds": sds((b, s, d), compute_dtype),
                     "tgt_tokens": sds((b, tgt), i32)}
        elif cfg.frontend == "vision":
            nf = cfg.n_frontend_tokens
            batch = {"tokens": sds((b, s - nf), i32),
                     "patch_embeds": sds((b, nf, d), compute_dtype)}
        else:
            batch = {"tokens": sds((b, s), i32)}
        return {"batch": batch}
    # decode: one token against a cache of length s
    state = abstract_decode_state(cfg, b, s, compute_dtype)
    return {"tokens": sds((b, 1), i32), "state": state}


def abstract_decode_state(cfg, b, s, compute_dtype=jnp.bfloat16):
    ng = cfg.n_layers // blocks.group_size(cfg)
    proto = jax.eval_shape(
        lambda: blocks.empty_group_cache(cfg, b, s, jnp.bfloat16))
    caches = jax.tree.map(
        lambda l: sds((ng,) + l.shape, l.dtype), proto)
    state = {"caches": caches, "index": sds((), jnp.int32)}
    if cfg.is_encoder_decoder:
        kvh, hd = cfg.n_kv_heads, cfg.head_dim
        state["cross"] = (sds((ng, b, s, kvh, hd), compute_dtype),
                          sds((ng, b, s, kvh, hd), compute_dtype))
    return state
