"""Mixture-of-Experts MLP with capacity-based scatter dispatch.

Dispatch is the scatter/gather formulation (not the (T, E, C) one-hot
einsum, whose dispatch tensor would be ~10^11 elements at 1M tokens):

  1. top-k routing per token;
  2. position-in-expert via a cumulative sum over the (T*k, E) one-hot;
  3. tokens scatter into an (E, C, d) expert buffer (over-capacity tokens
     drop, weights renormalised);
  4. batched expert SwiGLU einsum — under pjit the expert axis shards on
     the ``model`` mesh axis (expert parallelism), and the scatter/gather
     lowers to the all-to-all exchange of a classic EP implementation;
  5. gather back + combine with router weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

# §Perf hillclimb lever: position-in-expert via associative_scan
# (O(T log T)) instead of cumsum's reduce-window (O(T^2) in XLA's cost
# model).  Toggled by the dry-run's --moe-scan flag for A/B.
DISPATCH_SCAN = False

# §Perf hillclimb lever 2 (granite cell, iter 2): number of dispatch
# groups.  0 = one global dispatch (scatter crosses data shards; SPMD
# lowers it to a replicate+all-reduce of the full expert buffer).  With
# G == data-axis size and a P(("pod","data")) constraint on the group
# dim, routing/scatter/expert-compute are fully LOCAL to each data shard
# (experts replicated over data, TP over model) — zero token exchange.
# Capacity becomes per-group, as in Switch-Transformer's group-wise
# dispatch.
DISPATCH_GROUPS = 0
GROUP_AXES = ("data",)  # mesh axes the group dim is sharded over
MESH = None             # set by the dry-run for explicit NamedSharding


def init_moe(key, cfg, moe, dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, moe.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), dtype=dtype),
        "w_gate": dense_init(ks[1], (E, d, ff), dtype=dtype),
        "w_up": dense_init(ks[2], (E, d, ff), dtype=dtype),
        "w_down": dense_init(ks[3], (E, ff, d), dtype=dtype),
    }


def capacity(n_tokens: int, moe) -> int:
    c = int(-(-n_tokens * moe.top_k * moe.capacity_factor // moe.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly layout


def moe_mlp(params, x, moe, *, return_aux=False):
    """x: (..., d) -> (..., d).  Internally flattens to (T, d)."""
    orig_shape = x.shape
    d = x.shape[-1]
    x = x.reshape(-1, d)
    T = x.shape[0]
    if DISPATCH_GROUPS and T % DISPATCH_GROUPS == 0 and \
            T // DISPATCH_GROUPS >= moe.n_experts:
        G = DISPATCH_GROUPS
        xg = x.reshape(G, T // G, d)
        P = jax.sharding.PartitionSpec
        spec = P(GROUP_AXES if len(GROUP_AXES) > 1 else GROUP_AXES[0],
                 None, None)
        if MESH is not None:
            xg = jax.lax.with_sharding_constraint(
                xg, jax.sharding.NamedSharding(MESH, spec))
        out = jax.vmap(lambda xs: _moe_mlp_flat(params, xs, moe))(xg)
        if MESH is not None:
            out = jax.lax.with_sharding_constraint(
                out, jax.sharding.NamedSharding(MESH, spec))
        return out.reshape(orig_shape)
    out = _moe_mlp_flat(params, x, moe, return_aux=return_aux)
    if return_aux:
        return out[0].reshape(orig_shape), out[1]
    return out.reshape(orig_shape)


def _moe_mlp_flat(params, x, moe, *, return_aux=False):
    T, d = x.shape
    E, k = moe.n_experts, moe.top_k
    C = capacity(T, moe)
    dt = x.dtype

    router_logits = (x.astype(jnp.float32)
                     @ params["router"].astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- dispatch: position of each (token, choice) within its expert -----
    eid = idx.reshape(-1)  # (T*k,)
    oh = jax.nn.one_hot(eid, E, dtype=jnp.int32)  # (T*k, E)
    if DISPATCH_SCAN:
        # log-depth prefix sum: jnp.cumsum lowers to a reduce-window that
        # XLA's cost model (and some backends) treat as O(T^2); the
        # associative_scan form is O(T log T) ops — at 8M slot-tokens this
        # is the difference between the MoE layer being compute-
        # pathological and free (EXPERIMENTS.md §Perf, granite hillclimb)
        pos_all = jax.lax.associative_scan(jnp.add, oh, axis=0)
    else:  # paper-faithful-baseline dispatch (pre-hillclimb)
        pos_all = jnp.cumsum(oh, axis=0)
    pos = jnp.take_along_axis(pos_all - 1, eid[:, None], axis=1)[:, 0]
    keep = pos < C
    dst = jnp.where(keep, eid * C + pos, E * C)  # drop slot at the end

    x_rep = jnp.repeat(x, k, axis=0)  # (T*k, d) token i -> rows i*k..i*k+k-1
    buf = jnp.zeros((E * C + 1, d), dt).at[dst].set(x_rep)
    eb = buf[: E * C].reshape(E, C, d)

    # --- expert computation (batched einsum; shards on expert axis) ------
    g = jnp.einsum("ecd,edf->ecf", eb, params["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", eb, params["w_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   params["w_down"].astype(dt))

    # --- combine ----------------------------------------------------------
    y_flat = jnp.concatenate([y.reshape(E * C, d),
                              jnp.zeros((1, d), dt)], axis=0)
    y_tok = y_flat[dst]  # (T*k, d); dropped rows read zeros
    w = (gate.reshape(-1) * keep.astype(jnp.float32)).astype(dt)
    out = (y_tok * w[:, None]).reshape(T, k, d).sum(axis=1)
    if return_aux:
        # load-balancing loss (Switch): E * sum_e f_e * p_e
        me = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=(0, 1))
        pe = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(me * pe)
        return out, aux
    return out
