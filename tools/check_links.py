#!/usr/bin/env python3
"""Verify that every relative markdown link in README.md and docs/*.md
resolves to a real file (CI docs job).

Checks ``[text](target)`` links whose target has no URL scheme; targets
are resolved relative to the file containing the link, ``#anchors`` are
stripped (anchor existence is not validated — only that the file
exists).  Exits non-zero listing every broken link.

  python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SCHEME_RE = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def check_file(md: Path, root: Path) -> list:
    broken = []
    for target in LINK_RE.findall(md.read_text()):
        if SCHEME_RE.match(target) or target.startswith("#"):
            continue                        # external URL / in-page anchor
        path = target.split("#", 1)[0]
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            broken.append((md.relative_to(root), target))
    return broken


def main() -> int:
    root = Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    broken = []
    checked = 0
    for md in files:
        if not md.exists():
            broken.append((md.relative_to(root), "<file missing>"))
            continue
        checked += 1
        broken += check_file(md, root)
    if broken:
        for src, target in broken:
            print(f"BROKEN: {src}: {target}")
        return 1
    print(f"all relative links resolve across {checked} markdown files")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
