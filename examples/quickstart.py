"""Quickstart: the QRMark pipeline in ~60 lines.

1. Build (or load) a tile watermark encoder/extractor pair.
2. RS-encode a 48-bit key and embed it into images.
3. Detect with the full QRMark pipeline (fused preprocess kernel,
   random-grid tiling, on-device batched Berlekamp-Welch).

  PYTHONPATH=src python examples/quickstart.py
"""
import pickle
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.extractor import encoder_forward, extractor_forward
from repro.core.rs import jax_rs
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.core.train_extractor import ExtractorTrainConfig, train
from repro.data.pipeline import synth_image

EXTRACTOR = Path("experiments/extractor/tile16_params.pkl")


def get_pair():
    if EXTRACTOR.exists():
        with open(EXTRACTOR, "rb") as f:
            d = pickle.load(f)
        print(f"loaded trained pair from {EXTRACTOR}")
        return d["params"], d["cfg"]
    print("no trained pair found - training a tiny one (~2 min on CPU)")
    cfg = ExtractorTrainConfig(steps=80, batch=16, tile=16, img_size=64,
                               channels=16, depth=3, enc_channels=12,
                               enc_depth=2, curriculum_frac=1.0)
    return train(cfg, log_every=40)["params"], cfg


def main():
    params, cfg = get_pair()
    code = cfg.code
    tile = cfg.tile

    # --- the 48-bit watermark key, RS-encoded to 60 bits ----------------
    rng = np.random.default_rng(0)
    key_bits = rng.integers(0, 2, code.message_bits)
    codeword = jnp.asarray(rs_encode(code, key_bits))
    print(f"key: {''.join(map(str, key_bits[:16]))}... "
          f"({code.message_bits}b -> RS({code.n},{code.k}) "
          f"{code.codeword_bits}b)")

    # --- embed into every grid tile of 8 images -------------------------
    size = tile * 4
    imgs = jnp.asarray(np.stack([synth_image(i, size) for i in range(8)]),
                       jnp.float32) / 127.5 - 1.0
    tiles = tiling.grid_partition(imgs, tile)
    b, g = tiles.shape[:2]
    cw = jnp.broadcast_to(codeword, (b * g, code.codeword_bits))
    xw_flat, _ = encoder_forward(params["enc"],
                                 tiles.reshape(-1, tile, tile, 3), cw)
    gy = size // tile
    xw = xw_flat.reshape(b, gy, gy, tile, tile, 3).transpose(
        0, 1, 3, 2, 4, 5).reshape(b, size, size, 3)
    psnr = 10 * jnp.log10(4.0 / jnp.mean(jnp.square(xw - imgs)))
    print(f"embedded watermark at PSNR {float(psnr):.1f} dB")

    # --- detect: one random-grid tile per image + batched on-device RS --
    sel, _ = tiling.select_tiles("random_grid", jax.random.key(1), xw,
                                 tile)
    logits = extractor_forward(params["dec"], sel)
    bits = (logits > 0).astype(jnp.int32)
    out = jax_rs.make_batch_decoder(code)(bits)
    ok = np.asarray(out["ok"])
    rec = np.asarray(out["message_bits"])
    match = ok & np.all(rec == key_bits[None, :], axis=1)
    raw_acc = float((np.asarray(bits) == np.asarray(codeword)).mean())
    print(f"raw tile bit accuracy : {raw_acc:.3f}")
    print(f"RS-corrected recovery : {match.sum()}/{len(match)} images")
    print("QRMark quickstart complete.")


if __name__ == "__main__":
    main()
