"""Example: batched watermark-detection serving with QRMark's adaptive
lane allocation (Algorithm 1), LPT mini-batch scheduling (Algorithm 2),
inter-batch interleaving, and the fused preprocess kernel — compared
against the sequential baseline.

  PYTHONPATH=src python examples/serve_detection.py [--batches 6]
"""
import argparse
import pickle
import time
from pathlib import Path

import numpy as np

from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.data.pipeline import synth_image
from repro.launch.serve import DetectionService

EXTRACTOR_CANDIDATES = [Path("experiments/extractor/tile32_params.pkl"),
                        Path("experiments/extractor/tile16_params.pkl")]


def load_pair():
    for p in EXTRACTOR_CANDIDATES:
        if p.exists():
            with open(p, "rb") as f:
                d = pickle.load(f)
            return d["params"], d["cfg"]
    raise SystemExit("train an extractor first: "
                     "PYTHONPATH=src python examples/train_extractor.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    params, tcfg = load_pair()
    raw_size = 160
    batches = [np.stack([synth_image(k * args.batch + i, raw_size)
                         for i in range(args.batch)])
               for k in range(args.batches)]

    # --- sequential baseline --------------------------------------------
    base = DetectionPipeline(DetectionConfig(
        tile=tcfg.tile, img_size=128, resize_src=144, mode="sequential",
        rs_mode="cpu_sync", fused_preprocess=False, interleave=False,
        code=tcfg.code), params["dec"])
    r0 = base.run_stream(batches)
    base.close()
    print(f"sequential baseline : {r0['throughput_ips']:8.1f} img/s")

    # --- QRMark service with adaptive allocation -------------------------
    svc = DetectionService(DetectionConfig(
        tile=tcfg.tile, img_size=128, resize_src=144, mode="qrmark",
        rs_mode="device", code=tcfg.code), params["dec"], lane_budget=8)
    alloc = svc.warmup(batches[0])
    print(f"adaptive allocation : streams={alloc.streams} "
          f"(pre/decode/RS), predicted J*={alloc.bottleneck_s * 1e3:.2f}ms")
    rep = svc.serve(batches)
    print(f"qrmark service      : {rep.throughput_ips:8.1f} img/s "
          f"({rep.throughput_ips / max(r0['throughput_ips'], 1e-9):.2f}x)")
    print(f"straggler re-issues : {rep.straggler_retries}")


if __name__ == "__main__":
    main()
