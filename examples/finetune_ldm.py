"""Example: QRMark §4.2 — fine-tune the (stand-in) LDM decoder D_m so
every generated image carries the RS-encoded signature m_s, recoverable
by the frozen tile extractor H_D from a single random-grid tile.

  PYTHONPATH=src python examples/finetune_ldm.py [--steps 120]
"""
import argparse
import pickle
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ldm, tiling
from repro.core.extractor import extractor_forward
from repro.core.rs import jax_rs
from repro.data.pipeline import synth_image

EXTRACTOR = Path("experiments/extractor/tile16_params.pkl")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--img", type=int, default=64)
    args = ap.parse_args()

    # frozen extractor H_D from the offline stage
    if EXTRACTOR.exists():
        with open(EXTRACTOR, "rb") as f:
            d = pickle.load(f)
        hd, code, tile = d["params"]["dec"], d["cfg"].code, d["cfg"].tile
        print(f"loaded extractor (tile {tile})")
    else:
        raise SystemExit("run examples/train_extractor.py --tile 16 first")

    print("[1/3] pretraining the autoencoder (stand-in LDM VAE)...")
    ae = ldm.pretrain_autoencoder(jax.random.key(0), img_size=args.img,
                                  steps=120, batch=8, verbose=True)

    print("[2/3] fine-tuning D_m against the frozen extractor...")
    res = ldm.finetune_decoder(ae, hd, code=code, tile=tile,
                               img_size=args.img, steps=args.steps,
                               batch=4, lr=5e-3, lam_i=0.1, verbose=True)

    print("[3/3] verifying: generate -> tile -> extract -> RS decode")
    imgs = np.stack([synth_image(9_000_000 + i, args.img)
                     for i in range(16)])
    x = jnp.asarray(imgs, jnp.float32) / 127.5 - 1.0
    z = ldm.encode(ae, x)
    xw = ldm.decode(res.decoder, z)  # watermarked reconstructions
    sel, _ = tiling.select_tiles("random_grid", jax.random.key(7), xw,
                                 tile)
    logits = extractor_forward(hd, sel)
    bits = (logits > 0).astype(jnp.int32)
    out = jax_rs.make_batch_decoder(code)(bits)
    gt = res.signature[: code.message_bits]
    ok = np.asarray(out["ok"])
    hit = ok & np.all(np.asarray(out["message_bits"]) == gt[None], axis=1)
    raw = float((np.asarray(bits) == res.signature[None]).mean())
    print(f"raw tile bit accuracy : {raw:.3f}")
    print(f"RS-exact recovery     : {hit.sum()}/{len(hit)} generations")
    mse = float(jnp.mean(jnp.square(xw - ldm.decode(ae['dec'], z))))
    print(f"distortion vs D(z)    : mse {mse:.5f}")
    if raw < 0.95:
        print("note: the 3-conv stand-in decoder saturates below the "
              "paper's pretrained LDM; accuracy keeps rising with "
              "--steps (mechanism check: should exceed 0.6 vs the 0.5 "
              "chance floor)")
    assert raw > 0.6, "fine-tune failed to move extraction accuracy"


if __name__ == "__main__":
    main()
