"""Example: QRMark offline stage — train the tile-based watermark
encoder/extractor pair with the RS-aware loss, then evaluate accuracy
under the paper's attack set and save checkpoints.

Usage:
  PYTHONPATH=src python examples/train_extractor.py \
      --tile 32 --steps 400 --out experiments/extractor
"""
import argparse
import json
import pickle
import sys
import time
from pathlib import Path

import numpy as np

from repro.core.train_extractor import (ExtractorTrainConfig, evaluate,
                                        train)
from repro.core import transforms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tile", type=int, default=32)
    ap.add_argument("--img-size", type=int, default=128)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--channels", type=int, default=24)
    ap.add_argument("--out", default="experiments/extractor")
    ap.add_argument("--eval-images", type=int, default=128)
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    cfg = ExtractorTrainConfig(tile=args.tile, img_size=args.img_size,
                               steps=args.steps, batch=args.batch,
                               channels=args.channels)
    tag = args.tag or f"tile{args.tile}"
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    print(f"[train_extractor] {tag}: tile={cfg.tile} steps={cfg.steps} "
          f"code=({cfg.code.n},{cfg.code.k}) over GF(2^{cfg.code.m})",
          flush=True)
    t0 = time.time()
    result = train(cfg, log_every=25)
    params = result["params"]

    # persist BEFORE eval so a failed eval never loses the training run
    with open(out_dir / f"{tag}_params.pkl", "wb") as f:
        pickle.dump({"params": params, "cfg": cfg}, f)

    attacks = ("none",) + transforms.STABLE_SIG_ATTACKS
    ev = evaluate(params, cfg, n_images=args.eval_images, attacks=attacks)
    for atk, r in ev.items():
        print(f"  {atk:14s} bit_acc={r['bit_acc']:.3f} "
              f"rs_word_acc={r.get('rs_word_acc', float('nan')):.3f} "
              f"psnr={r['psnr']:.1f}", flush=True)

    with open(out_dir / f"{tag}_params.pkl", "wb") as f:
        pickle.dump({"params": params, "cfg": cfg}, f)
    (out_dir / f"{tag}_report.json").write_text(json.dumps({
        "history": result["history"], "eval": ev,
        "wall_s": time.time() - t0,
        "config": {"tile": cfg.tile, "img_size": cfg.img_size,
                   "steps": cfg.steps, "batch": cfg.batch,
                   "code": [cfg.code.m, cfg.code.n, cfg.code.k]},
    }, indent=1))
    print(f"[train_extractor] saved {tag} in {time.time()-t0:.0f}s",
          flush=True)


if __name__ == "__main__":
    main()
