"""Per-kernel shape/dtype sweeps: pallas_call (interpret mode) vs the
pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_preprocess import fused_preprocess
from repro.kernels import ref as kref


@pytest.mark.parametrize("H,W,resize,crop", [
    (256, 256, 256, 256),
    (512, 512, 288, 256),
    (300, 400, 256, 224),
    (64, 64, 48, 32),
    (128, 96, 80, 64),
])
def test_fused_preprocess_shapes(H, W, resize, crop):
    rng = np.random.default_rng(0)
    raw = jnp.asarray(rng.integers(0, 256, (2, H, W, 3), dtype=np.uint8))
    out = fused_preprocess(raw, resize=resize, crop=crop, interpret=True)
    ref = kref.fused_preprocess_ref(raw, resize=resize, crop=crop)
    assert out.shape == (2, crop, crop, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
def test_fused_preprocess_dtypes(dtype):
    rng = np.random.default_rng(1)
    if dtype == np.uint8:
        raw = rng.integers(0, 256, (3, 96, 96, 3), dtype=np.uint8)
    else:
        raw = rng.uniform(0, 255, (3, 96, 96, 3)).astype(np.float32)
    out = fused_preprocess(jnp.asarray(raw), resize=64, crop=48,
                           interpret=True)
    ref = kref.fused_preprocess_ref(jnp.asarray(raw), resize=64, crop=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=1e-4)


def test_fused_preprocess_custom_stats():
    rng = np.random.default_rng(2)
    raw = jnp.asarray(rng.integers(0, 256, (1, 80, 80, 3), dtype=np.uint8))
    mean = np.array([0.5, 0.5, 0.5], np.float32)
    std = np.array([0.5, 0.5, 0.5], np.float32)
    out = fused_preprocess(raw, resize=80, crop=80, mean=mean, std=std,
                           interpret=True)
    ref = kref.fused_preprocess_ref(raw, resize=80, crop=80, mean=mean,
                                    std=std)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4)


def test_resize_matrix_matches_jax_image():
    """The interpolation-matrix trick must equal jax.image bilinear."""
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, (40, 7)).astype(np.float32)
    M = kref.resize_matrix(40, 28)
    ref = jax.image.resize(jnp.asarray(x), (28, 7), method="bilinear",
                           antialias=False)
    np.testing.assert_allclose(M @ x, np.asarray(ref), atol=1e-5)
