"""Per-kernel shape/dtype sweeps: pallas_call (interpret mode) vs the
pure-jnp oracle in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiling
from repro.kernels.fused_preprocess import fused_preprocess
from repro.kernels.fused_tile_preprocess import fused_tile_preprocess
from repro.kernels import ref as kref


@pytest.mark.parametrize("H,W,resize,crop", [
    (256, 256, 256, 256),
    (512, 512, 288, 256),
    (300, 400, 256, 224),
    (64, 64, 48, 32),
    (128, 96, 80, 64),
])
def test_fused_preprocess_shapes(H, W, resize, crop):
    rng = np.random.default_rng(0)
    raw = jnp.asarray(rng.integers(0, 256, (2, H, W, 3), dtype=np.uint8))
    out = fused_preprocess(raw, resize=resize, crop=crop, interpret=True)
    ref = kref.fused_preprocess_ref(raw, resize=resize, crop=crop)
    assert out.shape == (2, crop, crop, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [np.uint8, np.float32])
def test_fused_preprocess_dtypes(dtype):
    rng = np.random.default_rng(1)
    if dtype == np.uint8:
        raw = rng.integers(0, 256, (3, 96, 96, 3), dtype=np.uint8)
    else:
        raw = rng.uniform(0, 255, (3, 96, 96, 3)).astype(np.float32)
    out = fused_preprocess(jnp.asarray(raw), resize=64, crop=48,
                           interpret=True)
    ref = kref.fused_preprocess_ref(jnp.asarray(raw), resize=64, crop=48)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=1e-4)


def test_fused_preprocess_custom_stats():
    rng = np.random.default_rng(2)
    raw = jnp.asarray(rng.integers(0, 256, (1, 80, 80, 3), dtype=np.uint8))
    mean = np.array([0.5, 0.5, 0.5], np.float32)
    std = np.array([0.5, 0.5, 0.5], np.float32)
    out = fused_preprocess(raw, resize=80, crop=80, mean=mean, std=std,
                           interpret=True)
    ref = kref.fused_preprocess_ref(raw, resize=80, crop=80, mean=mean,
                                    std=std)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4)


# ---------------------------------------------------------------------------
# tile-first fused ingest kernel
# ---------------------------------------------------------------------------


def _tile_geometry(tile):
    """(crop, resize, raw) for a tile size — crop = 2x2 grid of tiles."""
    crop = 2 * tile
    return crop, crop + max(tile // 4, 8), crop + 32


@pytest.mark.parametrize("strategy", tiling.STRATEGIES)
@pytest.mark.parametrize("tile", [32, 64, 128])
def test_fused_tile_preprocess_bit_exact_vs_staged(strategy, tile):
    """The tentpole contract: slicing the interpolation matrices before
    the matmuls == slicing the full preprocessed image after them, bit
    for bit, for every strategy and tile size."""
    crop, resize, raw_hw = _tile_geometry(tile)
    rng = np.random.default_rng(tile)
    raw = jnp.asarray(rng.integers(0, 256, (2, raw_hw, raw_hw, 3),
                                   dtype=np.uint8))
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(7), i))(
        jnp.arange(2))
    offs = tiling.tile_first_offsets(strategy, keys, img_size=crop,
                                     tile=tile)
    out = fused_tile_preprocess(raw, offs, resize=resize, crop=crop,
                                tile=tile, interpret=True)
    full = fused_preprocess(raw, resize=resize, crop=crop, interpret=True)
    staged = tiling.extract_tiles(full, offs, tile)
    assert out.shape == (2, tile, tile, 3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(staged))


@pytest.mark.parametrize("b", [1, 3])
def test_fused_tile_preprocess_ragged_batches(b):
    rng = np.random.default_rng(b)
    raw = jnp.asarray(rng.integers(0, 256, (b, 96, 96, 3),
                                   dtype=np.uint8))
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(b), i))(
        jnp.arange(b))
    offs = tiling.tile_first_offsets("random_grid", keys, img_size=64,
                                     tile=32)
    out = fused_tile_preprocess(raw, offs, resize=72, crop=64, tile=32,
                                interpret=True)
    full = fused_preprocess(raw, resize=72, crop=64, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(tiling.extract_tiles(full, offs, 32)))


def test_fused_tile_preprocess_matches_oracle():
    """allclose against the jnp oracle (jax.image.resize + slice)."""
    rng = np.random.default_rng(11)
    raw = jnp.asarray(rng.integers(0, 256, (3, 128, 96, 3),
                                   dtype=np.uint8))
    offs = jnp.asarray([[0, 0], [16, 48], [48, 16]], jnp.int32)
    out = fused_tile_preprocess(raw, offs, resize=80, crop=64, tile=16,
                                interpret=True)
    ref = kref.fused_tile_preprocess_ref(raw, offs, resize=80, crop=64,
                                         tile=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-4, rtol=1e-4)


def test_fused_tile_preprocess_logits_bit_exact():
    """End of the ingest contract: the extractor's logits on tile-first
    tiles equal those on staged preprocess -> select_tiles_per_image."""
    from repro.core.extractor import extractor_forward, init_extractor
    rng = np.random.default_rng(5)
    raw = jnp.asarray(rng.integers(0, 256, (3, 96, 96, 3),
                                   dtype=np.uint8))
    params = init_extractor(jax.random.key(1), n_bits=12, channels=4,
                            depth=1)
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.key(2), i))(
        jnp.arange(3))
    offs = tiling.tile_first_offsets("random_grid", keys, img_size=64,
                                     tile=32)
    tiles_tf = fused_tile_preprocess(raw, offs, resize=72, crop=64,
                                     tile=32, interpret=True)
    full = fused_preprocess(raw, resize=72, crop=64, interpret=True)
    tiles_staged, offs2 = tiling.select_tiles_per_image(
        "random_grid", keys, full, 32)
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(offs2))
    np.testing.assert_array_equal(
        np.asarray(extractor_forward(params, tiles_tf)),
        np.asarray(extractor_forward(params, tiles_staged)))


def test_resize_matrix_matches_jax_image():
    """The interpolation-matrix trick must equal jax.image bilinear."""
    rng = np.random.default_rng(3)
    x = rng.uniform(0, 1, (40, 7)).astype(np.float32)
    M = kref.resize_matrix(40, 28)
    ref = jax.image.resize(jnp.asarray(x), (28, 7), method="bilinear",
                           antialias=False)
    np.testing.assert_allclose(M @ x, np.asarray(ref), atol=1e-5)
