"""Adaptive multi-tile escalation tests.

Covers the full feature stack: k-tile offset plans (column-0
bit-identity, non-colliding random_grid cells), the (b, k, 2) tile-first
kernel form, the EscalationPolicy triggers (RS failure + thin margin),
bit-identity of every engine at escalate_tiles=1 AND at k>1, and the
online server's re-submitted escalation micro-batches.

The workload is the correlation-margined synthetic detector also used
by benchmarks/fig12_escalation.py: encoder and extractor share the
spread-spectrum pattern bank and the (untrained, noisy) conv/head path
is zeroed, so logits carry a real margin without trained artifacts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiling
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.extractor import (encoder_forward, init_encoder,
                                  init_extractor)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.core.stages import EscalationPolicy
from repro.data.pipeline import synth_image
from repro.kernels.fused_tile_preprocess import fused_tile_preprocess
from repro.kernels.ref import fused_tile_preprocess_ref

TILE, IMG, B = 16, 48, 6
_FIELDS = ("message_bits", "ok", "n_corrected", "logits")


def _keys(n, seed=0):
    return jax.vmap(lambda i: jax.random.fold_in(
        jax.random.key(seed), i))(jnp.arange(n))


# ---------------------------------------------------------------------------
# escalation offset plans
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", tiling.STRATEGIES)
def test_escalation_offsets_column0_is_the_single_tile_draw(strategy):
    """Round 1 of any escalation plan must decode EXACTLY the tile the
    single-tile pipeline picks (the bit-identity anchor)."""
    keys = _keys(7)
    single = tiling.per_image_offsets(strategy, keys, (64, 64), 16)
    for k in (1, 2, 4):
        plan = tiling.escalation_offsets(strategy, keys, (64, 64), 16, k)
        assert plan.shape == (7, k, 2)
        np.testing.assert_array_equal(np.asarray(plan[:, 0]),
                                      np.asarray(single))


def test_escalation_offsets_random_grid_cells_never_collide():
    """random_grid plans are per-image permutations: at k == gy*gx every
    cell appears exactly once, grid-aligned."""
    keys = _keys(9, seed=3)
    plan = np.asarray(
        tiling.escalation_offsets("random_grid", keys, (64, 64), 16, 16))
    assert (plan % 16 == 0).all()
    cells = plan[..., 0] // 16 * 4 + plan[..., 1] // 16
    for row in cells:
        assert sorted(row) == list(range(16)), "colliding/missing cell"


def test_escalation_offsets_fixed_is_raster_order():
    keys = _keys(3)
    plan = np.asarray(
        tiling.escalation_offsets("fixed", keys, (48, 48), 16, 4))
    expect = np.array([[0, 0], [0, 16], [0, 32], [16, 0]]) \
        [None].repeat(3, axis=0)
    np.testing.assert_array_equal(plan, expect)


def test_escalation_offsets_random_stays_in_bounds():
    keys = _keys(50, seed=9)
    plan = np.asarray(
        tiling.escalation_offsets("random", keys, (40, 40), 16, 3))
    assert plan.min() >= 0 and plan.max() <= 40 - 16


def test_escalation_offsets_rejects_over_budget():
    keys = _keys(2)
    with pytest.raises(ValueError, match="at most"):
        tiling.escalation_offsets("random_grid", keys, (32, 32), 16, 5)
    with pytest.raises(ValueError, match="at most"):
        tiling.escalation_offsets("fixed", keys, (32, 32), 16, 5)


def test_config_validation():
    params = init_extractor(jax.random.key(0), n_bits=60, channels=4,
                            depth=1)
    with pytest.raises(ValueError, match="sequential"):
        DetectionPipeline(DetectionConfig(
            mode="sequential", escalate_tiles=2), params)
    with pytest.raises(ValueError, match="exceeds"):
        DetectionPipeline(DetectionConfig(
            tile=16, img_size=32, escalate_tiles=5), params)
    with pytest.raises(ValueError, match=">= 1"):
        DetectionPipeline(DetectionConfig(escalate_tiles=0), params)
    with pytest.raises(ValueError, match="no effect"):
        DetectionPipeline(DetectionConfig(escalate_margin=0.5), params)


# ---------------------------------------------------------------------------
# the (b, k, 2) kernel form
# ---------------------------------------------------------------------------


def test_ktile_kernel_matches_oracle_and_single_calls():
    rng = np.random.default_rng(0)
    raw = rng.integers(0, 256, (3, 40, 40, 3), dtype=np.uint8)
    offs = np.array([[0, 0], [8, 4], [16, 16]], np.int32)
    single = np.asarray(fused_tile_preprocess(
        raw, offs, resize=36, crop=32, tile=16))
    plan = np.stack([offs, offs[::-1]], axis=1)          # (3, 2, 2)
    out = np.asarray(fused_tile_preprocess(
        raw, plan, resize=36, crop=32, tile=16))
    ref = np.asarray(fused_tile_preprocess_ref(
        raw, plan, resize=36, crop=32, tile=16))
    assert out.shape == (6, 16, 16, 3)
    np.testing.assert_allclose(out, ref, atol=1e-4)
    # plan column 0 == the (b, 2) call, bitwise (image-major layout)
    np.testing.assert_array_equal(out[0::2], single)
    # the k=1 plan degenerates to the (b, 2) call, bitwise
    np.testing.assert_array_equal(
        np.asarray(fused_tile_preprocess(raw, offs[:, None, :],
                                         resize=36, crop=32, tile=16)),
        single)


# ---------------------------------------------------------------------------
# policy triggers
# ---------------------------------------------------------------------------


def test_policy_triggers():
    ok = np.array([True, False, True])
    logits = np.array([[2.0, -2.0], [2.0, 2.0], [0.1, -0.1]])
    assert not EscalationPolicy(1).enabled
    np.testing.assert_array_equal(
        EscalationPolicy(3).wants_escalation(ok, logits),
        [False, True, False])
    np.testing.assert_array_equal(
        EscalationPolicy(3, margin=0.5).wants_escalation(ok, logits),
        [False, True, True])


# ---------------------------------------------------------------------------
# end-to-end: the margined workload
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def workload():
    """Watermarked raw images + the corr-only detector that decodes
    them with a real margin (no trained artifacts needed)."""
    code = DEFAULT_CODE
    enc = init_encoder(jax.random.key(1), n_bits=code.codeword_bits,
                       channels=8, depth=2, tile=TILE)
    dec = init_extractor(jax.random.key(2), n_bits=code.codeword_bits,
                         channels=8, depth=2, tile=TILE,
                         patterns=enc["patterns"])
    dec["head"]["w"] = dec["head"]["w"] * 0.0   # corr path only
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))
    imgs = jnp.asarray(np.stack([synth_image(i, IMG) for i in range(B)]),
                       jnp.float32) / 127.5 - 1.0
    flat = tiling.grid_partition(imgs, TILE).reshape(-1, TILE, TILE, 3)
    xw, _ = encoder_forward(
        enc, flat, jnp.broadcast_to(cw, (flat.shape[0],
                                         code.codeword_bits)),
        embed_rms=0.2)
    g = IMG // TILE
    xw = xw.reshape(B, g, g, TILE, TILE, 3).transpose(
        0, 1, 3, 2, 4, 5).reshape(B, IMG, IMG, 3)
    raw = np.asarray((xw + 1.0) * 127.5, np.float32)
    return {"dec": dec, "msg": msg, "raw": raw, "code": code}


def _cfg(k=1, margin=0.0, **kw):
    base = dict(tile=TILE, img_size=IMG, resize_src=IMG, mode="qrmark",
                rs_mode="device", code=DEFAULT_CODE, escalate_tiles=k,
                escalate_margin=margin)
    base.update(kw)
    return DetectionConfig(**base)


def _corrupt_round1_tile(raw, pipe, key, fill=None, sigma=None, rng=None):
    """Damage exactly the tile round 1 will select for each image."""
    keys = pipe.stages.image_keys(key, raw.shape[0])
    offs = np.asarray(tiling.tile_first_offsets(
        pipe.cfg.strategy, keys, img_size=pipe.cfg.img_size,
        tile=pipe.cfg.tile))
    out = raw.copy()
    for i, (y, x) in enumerate(offs):
        if fill is not None:
            out[i, y: y + TILE, x: x + TILE] = fill
        else:
            out[i, y: y + TILE, x: x + TILE] += rng.normal(
                0, sigma, (TILE, TILE, 3))
    return np.clip(out, 0, 255).astype(np.float32)


def test_escalation_recovers_noised_round1_tile(workload):
    """RS-failure-triggered escalation: noise on the selected tile makes
    round 1 fail; escalating to clean tiles recovers the exact message
    at sub-linear cost (most images settle in round 2)."""
    w = workload
    key = jax.random.key(5)
    p1 = DetectionPipeline(_cfg(1), w["dec"], ground_truth_bits=w["msg"])
    p3 = DetectionPipeline(_cfg(3), w["dec"], ground_truth_bits=w["msg"])
    raw_bad = _corrupt_round1_tile(w["raw"], p1, key, sigma=90,
                                   rng=np.random.default_rng(1))
    o1 = p1.detect_batch(raw_bad, key=key)
    o3 = p3.detect_batch(raw_bad, key=key)
    assert "tiles_used" not in o1          # k=1 keeps the old schema
    assert o1["match"].mean() <= 0.2, "corruption did not break round 1"
    assert o3["match"].mean() >= 0.8, "escalation failed to recover"
    assert (o3["tiles_used"] > 1).all()
    assert o3["tiles_used"].max() <= 3


def test_margin_trigger_catches_spurious_all_zero_codeword(workload):
    """A flat tile yields ~zero logits -> all-zero bits, which IS a
    valid RS codeword (linear code): RS reports ok on garbage.  The
    thin-margin trigger escalates anyway and recovers the real key."""
    w = workload
    key = jax.random.key(5)
    p1 = DetectionPipeline(_cfg(1), w["dec"], ground_truth_bits=w["msg"])
    raw_flat = _corrupt_round1_tile(w["raw"], p1, key, fill=128.0)
    o1 = p1.detect_batch(raw_flat, key=key)
    assert o1["ok"].all(), "expected the spurious all-zero decode"
    assert o1["match"].mean() == 0.0
    pm = DetectionPipeline(_cfg(3, margin=0.6), w["dec"],
                           ground_truth_bits=w["msg"])
    om = pm.detect_batch(raw_flat, key=key)
    assert om["match"].mean() == 1.0
    assert (om["tiles_used"] >= 2).all(), "margin trigger never fired"


def test_clean_images_never_escalate_and_stay_bit_identical(workload):
    """With round 1 succeeding everywhere, a k>1 pipeline takes the
    identical code path and produces bitwise identical results to
    k=1 (the escalate_tiles=1 contract extends to untriggered k>1)."""
    w = workload
    key = jax.random.key(5)
    p1 = DetectionPipeline(_cfg(1), w["dec"], ground_truth_bits=w["msg"])
    p3 = DetectionPipeline(_cfg(3), w["dec"], ground_truth_bits=w["msg"])
    o1 = p1.detect_batch(w["raw"], key=key)
    o3 = p3.detect_batch(w["raw"], key=key)
    assert o1["match"].all()
    assert (o3["tiles_used"] == 1).all()
    for f in _FIELDS:
        np.testing.assert_array_equal(o1[f], o3[f], err_msg=f)


def test_escalation_bit_identical_across_engines(workload):
    """detect_batch, run_stream (2 lanes), and the sharded run_batch
    must produce bitwise identical escalated results."""
    w = workload
    key = jax.random.key(5)
    mk = lambda: DetectionPipeline(_cfg(3), w["dec"],
                                   ground_truth_bits=w["msg"])
    p = mk()
    raw_bad = _corrupt_round1_tile(w["raw"], p, key, sigma=90,
                                   rng=np.random.default_rng(1))
    ref = p.detect_batch(raw_bad, key=key)
    shard = mk().run_batch(raw_bad, key=key)
    # run_stream derives batch 0's key from the seed: compare against a
    # fresh detect_batch doing the same
    stream = mk().run_stream([raw_bad], lanes=2)["results"][0]
    seq_ref = mk().detect_batch(raw_bad)
    fields = _FIELDS + ("tiles_used",)
    for f in fields:
        np.testing.assert_array_equal(ref[f], shard[f],
                                      err_msg=f"run_batch/{f}")
        np.testing.assert_array_equal(stream[f], seq_ref[f],
                                      err_msg=f"run_stream/{f}")


def test_always_k_decode_all_matches_per_round_tiles(workload):
    """decode_all_keyed (the (b, k, 2) kernel path) must equal the
    per-round escalation decodes stacked — same plan, same tiles,
    same soft bits."""
    w = workload
    p = DetectionPipeline(_cfg(3), w["dec"])
    reg = p.stages
    key = jax.random.key(7)
    keys = reg.image_keys(key, B)
    all_logits = np.asarray(reg.decode_all_keyed(w["raw"], keys))
    assert all_logits.shape == (B, 3, w["code"].codeword_bits)
    round0 = np.asarray(reg.decode_keyed(
        reg.ingest_keyed(w["raw"], keys), keys))
    np.testing.assert_array_equal(all_logits[:, 0], round0)
    for r in (1, 2):
        np.testing.assert_array_equal(
            all_logits[:, r],
            np.asarray(reg.escalate_round(w["raw"], keys, r)),
            err_msg=f"round {r}")


def test_padded_rows_never_escalate(workload):
    """Feeders that pad batches pass true_b: pad rows (repeats of the
    last real image) must not consume escalation rounds, and the real
    rows' results must equal the unpadded run bitwise."""
    w = workload
    key = jax.random.key(5)
    p = DetectionPipeline(_cfg(3), w["dec"], ground_truth_bits=w["msg"])
    raw_bad = _corrupt_round1_tile(w["raw"], p, key, sigma=90,
                                   rng=np.random.default_rng(1))
    padded = np.concatenate([raw_bad, raw_bad[-1:].repeat(2, axis=0)])
    ref = p.detect_batch(raw_bad, key=key)
    out = p.detect_batch(padded, key=key, true_b=B)
    assert (out["tiles_used"][B:] == 1).all(), "pad rows escalated"
    for f in _FIELDS + ("tiles_used",):
        np.testing.assert_array_equal(ref[f], out[f][:B], err_msg=f)
    # run_stream accepts (raw, true_b) items with the same guarantee
    stream = p.run_stream([(padded, B)], lanes=1)["results"][0]
    assert (stream["tiles_used"][B:] == 1).all()


# ---------------------------------------------------------------------------
# online server escalation
# ---------------------------------------------------------------------------


def test_server_escalation_bit_identical_and_metered(workload):
    """The server's re-submitted escalation micro-batches must produce
    results bitwise equal to offline detect_batch at the same config,
    and export escalation metrics."""
    from repro.serving import BatcherConfig, DetectionServer
    w = workload
    p3 = DetectionPipeline(_cfg(3), w["dec"])
    # requests of 2 images each; each request's round-1 tiles (selected
    # under ITS key) are noised so the online path must escalate
    keys = [jax.random.key(100 + i) for i in range(3)]
    reqs = [_corrupt_round1_tile(w["raw"][2 * i: 2 * i + 2], p3,
                                 keys[i], sigma=90,
                                 rng=np.random.default_rng(1 + i))
            for i in range(3)]
    srv = DetectionServer(
        _cfg(3), w["dec"],
        batcher=BatcherConfig(max_batch=4, max_wait_ms=2.0)).start()
    try:
        handles = [srv.submit(r, key=k) for r, k in zip(reqs, keys)]
        results = [h.result(300) for h in handles]
        stats = srv.stats()
    finally:
        srv.close()
    any_escalated = False
    for i, res in enumerate(results):
        ref = p3.detect_batch(reqs[i], key=keys[i])
        any_escalated |= bool((ref["tiles_used"] > 1).any())
        for f in _FIELDS + ("tiles_used",):
            np.testing.assert_array_equal(ref[f], res[f],
                                          err_msg=f"req {i}/{f}")
    assert any_escalated, "workload never escalated — test is vacuous"
    assert stats["counters"]["images_escalated"] > 0
    assert stats["escalation_batches"] > 0
    assert stats["escalation_rate"] > 0
    assert stats["tiles_per_image"]["n"] == 6
    assert stats["tiles_per_image"]["mean"] > 1.0


def test_server_without_escalation_keeps_old_schema(workload):
    """escalate_tiles=1 online results carry the pre-escalation result
    schema (no tiles_used) — nothing changed for existing clients."""
    from repro.serving import BatcherConfig, DetectionServer
    w = workload
    srv = DetectionServer(
        _cfg(1), w["dec"],
        batcher=BatcherConfig(max_batch=4, max_wait_ms=2.0)).start()
    try:
        res = srv.submit(w["raw"][:2], key=jax.random.key(0)).result(120)
    finally:
        srv.close()
    assert "tiles_used" not in res
    assert srv.registry.policy.enabled is False
