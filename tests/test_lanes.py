"""Lane-executor and sharded-batch correctness: any lane count must be
bit-identical to serial execution, order must be preserved, failures
must surface, and the data-parallel ``run_batch`` must match the
single-device path on a forced multi-device CPU mesh."""
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.extractor import init_extractor
from repro.core.lanes import LaneExecutor, Stage, lanes_from_allocation
from repro.core.rs.codec import DEFAULT_CODE
from repro.launch.serve import pad_to_bucket


# ---------------------------------------------------------------------------
# executor unit tests (plain python stages)
# ---------------------------------------------------------------------------


def test_executor_preserves_order_with_many_lanes():
    def jitter(x):
        time.sleep(0.001 * (x % 5))  # out-of-order completion
        return x * 2

    ex = LaneExecutor([Stage("a", jitter, lanes=4, depth=3),
                       Stage("b", lambda x: x + 1, lanes=3, depth=3)])
    assert ex.map(range(40)) == [i * 2 + 1 for i in range(40)]


def test_executor_propagates_stage_error_in_order():
    seen = []

    def boom(x):
        if x == 5:
            raise ValueError("boom")
        return x

    ex = LaneExecutor([Stage("s", boom, lanes=2, depth=2)])
    with pytest.raises(ValueError, match="boom"):
        for x in ex.run(range(10)):
            seen.append(x)
    assert seen == [0, 1, 2, 3, 4]  # everything before the failure


def test_executor_propagates_source_error_after_fed_items():
    def src():
        yield from range(3)
        raise RuntimeError("source died")

    ex = LaneExecutor([Stage("s", lambda x: x, lanes=2)])
    seen = []
    with pytest.raises(RuntimeError, match="source died"):
        for x in ex.run(src()):
            seen.append(x)
    assert seen == [0, 1, 2]


def test_executor_stage_concurrency_actually_overlaps():
    """With 4 lanes, 4 concurrent payloads must be in flight at once."""
    peak = [0]
    live = [0]
    lock = threading.Lock()

    def fn(x):
        with lock:
            live[0] += 1
            peak[0] = max(peak[0], live[0])
        time.sleep(0.02)
        with lock:
            live[0] -= 1
        return x

    ex = LaneExecutor([Stage("s", fn, lanes=4, depth=4)])
    ex.map(range(12))
    assert peak[0] >= 2, f"no overlap observed (peak in-flight {peak[0]})"


def test_executor_is_single_use():
    ex = LaneExecutor([Stage("s", lambda x: x)])
    assert ex.map(range(3)) == [0, 1, 2]
    with pytest.raises(RuntimeError, match="single-use"):
        ex.map(range(3))


def test_executor_bounds_in_flight_work():
    """A stalled consumer must backpressure the graph: the stage can't
    run arbitrarily far ahead of the sink (bounded queues end to end)."""
    prepared = []
    ex = LaneExecutor([Stage("s", lambda x: (prepared.append(x), x)[1],
                             depth=2)])
    gen = ex.run(range(100))
    next(gen)
    time.sleep(0.2)  # consumer stalls; worker should fill queues & block
    in_flight = len(prepared)
    ex.close()
    assert in_flight < 20, \
        f"stage ran {in_flight} items ahead of a stalled consumer"


def test_lanes_from_allocation():
    assert lanes_from_allocation(("ingest", "decode", "rs"), [1, 4, 0]) == \
        {"ingest": 1, "decode": 4, "rs": 1}


# ---------------------------------------------------------------------------
# detection pipeline through the executor
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_params():
    return init_extractor(jax.random.key(0),
                          n_bits=DEFAULT_CODE.codeword_bits,
                          channels=8, depth=2)


def _batches(n=5, b=4, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, (b, 64, 64, 3), dtype=np.uint8)
            for _ in range(n)]


def _collect(results):
    return (np.concatenate([r["message_bits"] for r in results]),
            np.concatenate([r["ok"] for r in results]),
            np.concatenate([r["logits"] for r in results]))


def test_lane_executor_matches_sequential_mode(tiny_params):
    """lanes>1 through the executor == the plain sequential-mode loop,
    bit for bit, on the same inputs."""
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="sequential", rs_mode="cpu_sync")
    data = _batches()
    serial = DetectionPipeline(cfg, tiny_params)
    ref = [serial.detect_batch(raw) for raw in data]
    laned = DetectionPipeline(cfg, tiny_params)
    out = laned.run_stream(data, lanes=3)
    assert out["lanes"] == {"ingest": 1, "decode": 3, "rs": 3}
    m0, ok0, lg0 = _collect(ref)
    m1, ok1, lg1 = _collect(out["results"])
    assert np.array_equal(m0, m1)
    assert np.array_equal(ok0, ok1)
    assert np.array_equal(lg0, lg1)


@pytest.mark.parametrize("rs_mode", ["device", "cpu_sync", "cpu_pool"])
def test_qrmark_lane_count_is_bit_identical(tiny_params, rs_mode):
    """qrmark with many lanes == qrmark with one lane per stage."""
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="qrmark", rs_mode=rs_mode, rs_threads=2)
    data = _batches(n=6)
    p1 = DetectionPipeline(cfg, tiny_params)
    p4 = DetectionPipeline(cfg, tiny_params)
    try:
        out1 = p1.run_stream(data, lanes=1)
        out4 = p4.run_stream(data, lanes=4)
        m0, ok0, lg0 = _collect(out1["results"])
        m1, ok1, lg1 = _collect(out4["results"])
        assert np.array_equal(m0, m1)
        assert np.array_equal(ok0, ok1)
        assert np.array_equal(lg0, lg1)
    finally:
        p1.close()
        p4.close()


def test_run_batch_ragged_padding_is_inert(tiny_params):
    """Per-image keys: a padded ragged batch must give every real image
    the same result as the unpadded single-device run."""
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="qrmark", rs_mode="device")
    raw7 = _batches(n=1, b=7)[0]
    pa = DetectionPipeline(cfg, tiny_params)
    pb = DetectionPipeline(cfg, tiny_params)
    padded, true_b = pad_to_bucket(raw7)   # -> 8 rows
    assert padded.shape[0] == 8 and true_b == 7
    out_a = pa.run_batch(raw7, key=jax.random.key(9))
    out_b = pb.run_batch(padded, key=jax.random.key(9))
    assert np.array_equal(out_a["message_bits"],
                          out_b["message_bits"][:7])
    assert np.array_equal(out_a["logits"], out_b["logits"][:7])


def test_run_stream_default_lanes_qrmark(tiny_params):
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="qrmark", rs_mode="device", lane_budget=6)
    pipe = DetectionPipeline(cfg, tiny_params)
    out = pipe.run_stream(_batches(n=3))
    assert out["images"] == 12
    assert sum(out["lanes"].values()) <= 6
    assert out["lanes"]["decode"] >= 1


# ---------------------------------------------------------------------------
# sharded run_batch on a forced 4-device CPU mesh (separate process:
# XLA_FLAGS must be set before jax initialises)
# ---------------------------------------------------------------------------


def test_sharded_run_batch_matches_single_device():
    script = Path(__file__).with_name("sharded_check.py")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    r = subprocess.run([sys.executable, str(script)], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "OK" in r.stdout
