"""Attack-registry tests (core/transforms.py): invariants every ATTACKS
entry must hold, JPEG quality ordering, and registry completeness
against the module's ``attack_*`` functions.

The attacks run on normalized float images (the detection pipeline's
tile space); each must preserve shape/dtype, stay finite, and stay
within a sane range of the clipped input domain so a benchmark sweep
(table3, fig12) can apply any registry entry blindly.
"""
import inspect

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transforms
from repro.core.transforms import ATTACKS, STABLE_SIG_ATTACKS


def _batch(seed=0, b=2, hw=24):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, 1.0, (b, hw, hw, 3)),
                       jnp.float32).clip(-2.0, 2.0)


@pytest.mark.parametrize("name", sorted(ATTACKS))
def test_attack_invariants(name):
    """Every registry entry: shape-, dtype-, and sanity-preserving."""
    x = _batch()
    y = ATTACKS[name](x)
    assert y.shape == x.shape, f"{name} changed the image shape"
    assert y.dtype == jnp.float32, f"{name} changed the dtype"
    y = np.asarray(y)
    assert np.isfinite(y).all(), f"{name} produced non-finite values"
    # inputs live in the clipped normalized domain; attacks may expand
    # it (brightness doubles, jpeg rings) but must stay bounded
    assert np.abs(y).max() <= 6.0, f"{name} exploded the value range"


def test_identity_attack_is_identity():
    x = _batch(1)
    np.testing.assert_array_equal(np.asarray(ATTACKS["none"](x)),
                                  np.asarray(x))


def test_jpeg_quality_ordering():
    """Higher JPEG quality must distort less: q=90 closer to the input
    than q=50, which is closer than q=10."""
    x = _batch(2, hw=32)
    err = {q: float(jnp.abs(transforms.attack_jpeg(x, q) - x).mean())
           for q in (10, 50, 90)}
    assert err[90] < err[50] < err[10], err
    assert err[90] < 0.5


def test_attacks_are_deterministic():
    x = _batch(3)
    for name, fn in ATTACKS.items():
        np.testing.assert_array_equal(np.asarray(fn(x)),
                                      np.asarray(fn(x)),
                                      err_msg=name)


def test_registry_covers_every_attack_function():
    """Every public ``attack_*`` function must be reachable from the
    ATTACKS registry (benchmarks sweep the registry, so an unregistered
    attack silently drops out of every evaluation)."""
    fns = [n[len("attack_"):] for n, f in
           inspect.getmembers(transforms, inspect.isfunction)
           if n.startswith("attack_")]
    assert fns, "no attack_* functions found"
    for stem in fns:
        hits = [k for k in ATTACKS
                if k == stem or k.startswith(stem + "_")]
        assert hits, f"attack_{stem} has no ATTACKS registry entry"


def test_registry_entries_map_to_functions():
    """Inverse direction: every registry key (except the identity) is
    named after an ``attack_*`` function."""
    for key in ATTACKS:
        if key == "none":
            continue
        stem = key.split("_")[0]
        candidates = [n for n in dir(transforms)
                      if n.startswith("attack_" + stem)]
        assert candidates, f"registry key {key!r} names no attack fn"


def test_stable_sig_set_is_subset_of_registry():
    missing = set(STABLE_SIG_ATTACKS) - set(ATTACKS)
    assert not missing, f"STABLE_SIG_ATTACKS not in registry: {missing}"
