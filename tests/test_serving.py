"""Online serving runtime tests: service-mode executor (submit/drain/
close/reconfigure), dynamic micro-batcher (deadline partial batches,
atomic groups, admission backpressure), and the DetectionServer's
correctness anchor — online results bit-identical to detect_batch for
any request interleaving, coalescing, bucket size, and lane config.

Executor/server tests wear the deadlock canary (tests/canary.py): a
queue/lock bug in the long-lived executor shows up as a hang, which
the canary turns into a failure with a message instead of a CI timeout.
"""
import threading
import time

import jax
import numpy as np
import pytest

from canary import deadline
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.extractor import init_extractor
from repro.core.lanes import LaneExecutor, Stage
from repro.core.rs.codec import DEFAULT_CODE
from repro.core.scheduler import StragglerPolicy
from repro.serving import (AdmissionError, BatcherConfig, DetectionServer,
                           MicroBatcher)
from repro.serving.batcher import pad_to_bucket
from repro.serving.metrics import MetricsRegistry, percentile

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# service-mode executor
# ---------------------------------------------------------------------------


@deadline(30)
def test_service_submit_out_of_order_completion():
    """Completions are delivered the moment they exist (callback order
    follows finish time, not submit order); results stay correct."""
    def jitter(x):
        time.sleep(0.02 if x == 0 else 0.001)
        return x * 10

    done = []
    ex = LaneExecutor([Stage("s", jitter, lanes=4, depth=4)]).start()
    tks = [ex.submit(i, callback=lambda t: done.append(t.seq))
           for i in range(8)]
    assert [t.result(10) for t in tks] == [i * 10 for i in range(8)]
    assert ex.drain(10)
    assert sorted(done) == list(range(8))
    assert done[-1] == 0, "slowest item should complete last (0 slept)"
    ex.close()


@deadline(30)
def test_service_submit_drain_ordering_regression():
    """submit -> drain -> submit again: the executor is long-lived."""
    ex = LaneExecutor([Stage("a", lambda x: x + 1, lanes=2),
                       Stage("b", lambda x: x * 2, lanes=2)]).start()
    r1 = [ex.submit(i) for i in range(10)]
    assert ex.drain(10)
    r2 = [ex.submit(i) for i in range(10, 20)]
    assert [t.result(10) for t in r1 + r2] == \
        [(i + 1) * 2 for i in range(20)]
    assert ex.pending() == 0
    ex.close()


@deadline(30)
def test_service_stage_error_rejects_only_that_ticket():
    def boom(x):
        if x == 2:
            raise ValueError("boom")
        return x

    ex = LaneExecutor([Stage("s", boom, lanes=2)]).start()
    tks = [ex.submit(i) for i in range(5)]
    for i, t in enumerate(tks):
        if i == 2:
            with pytest.raises(ValueError, match="boom"):
                t.result(10)
        else:
            assert t.result(10) == i
    ex.close()


@deadline(30)
def test_service_close_rejects_unresolved_tickets():
    gate = threading.Event()
    ex = LaneExecutor([Stage("s", lambda x: (gate.wait(5), x)[1],
                             depth=4)]).start()
    t = ex.submit(1)
    ex.close()          # without drain: ticket must reject, not hang
    gate.set()
    with pytest.raises(RuntimeError, match="closed"):
        t.result(10)
    with pytest.raises(RuntimeError, match="closed"):
        ex.submit(2)


@deadline(60)
def test_service_reconfigure_grows_and_shrinks_live():
    """Lane counts change under load without dropping or corrupting
    queued work (Algorithm 1 re-applied online)."""
    ex = LaneExecutor([Stage("s", lambda x: (time.sleep(0.002), x + 1)[1],
                             lanes=1, depth=8)]).start()
    tks = [ex.submit(i) for i in range(20)]
    assert ex.reconfigure({"s": 4}) == {"s": 4}
    tks += [ex.submit(i) for i in range(20, 40)]
    assert ex.reconfigure({"s": 2}) == {"s": 2}
    tks += [ex.submit(i) for i in range(40, 60)]
    assert [t.result(30) for t in tks] == [i + 1 for i in range(60)]
    assert ex.lane_counts() == {"s": 2}
    assert ex.drain(10)
    ex.close()


@deadline(30)
def test_run_and_start_are_mutually_exclusive():
    ex = LaneExecutor([Stage("s", lambda x: x)])
    assert ex.map(range(3)) == [0, 1, 2]
    with pytest.raises(RuntimeError):
        ex.start()
    ex2 = LaneExecutor([Stage("s", lambda x: x)]).start()
    with pytest.raises(RuntimeError):
        list(ex2.run(range(3)))
    ex2.close()


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------


def _imgs(n, seed=0):
    return np.random.default_rng(seed).integers(
        0, 256, (n, 8, 8, 3), dtype=np.uint8)


def _keys(n):
    return jax.vmap(lambda i: jax.random.fold_in(jax.random.key(0), i))(
        np.arange(n))


def test_pad_to_bucket_rejects_empty_batch():
    with pytest.raises(AdmissionError, match="empty"):
        pad_to_bucket(np.zeros((0, 8, 8, 3), np.uint8))
    # the launch-layer re-export is the same guarded function
    from repro.launch.serve import pad_to_bucket as serve_pad
    assert serve_pad is pad_to_bucket
    padded, b = pad_to_bucket(_imgs(3))
    assert padded.shape[0] == 4 and b == 3


def test_batcher_rejects_empty_and_oversized_requests():
    mb = MicroBatcher(BatcherConfig(max_batch=4))
    with pytest.raises(AdmissionError, match="empty"):
        mb.submit(_imgs(0), None, slot=None)
    with pytest.raises(AdmissionError, match="max_batch"):
        mb.submit(_imgs(5), _keys(5), slot=None)


@deadline(30)
def test_batcher_deadline_triggers_partial_batch():
    mb = MicroBatcher(BatcherConfig(max_batch=16, max_wait_ms=40.0))
    mb.submit(_imgs(3), _keys(3), slot="r0")
    t0 = time.perf_counter()
    out = mb.next_batch(timeout=5.0)
    waited = time.perf_counter() - t0
    assert out is not None
    assert out.true_b == 3 and out.padded_b == 4     # pow2 bucket
    assert out.slots == [("r0", 0, 3)]
    assert waited >= 0.02, "partial batch shipped before the deadline"


@deadline(30)
def test_batcher_coalesces_up_to_max_batch():
    mb = MicroBatcher(BatcherConfig(max_batch=4, max_wait_ms=500.0))
    for i in range(6):
        mb.submit(_imgs(1, seed=i), _keys(1), slot=i)
    t0 = time.perf_counter()
    out = mb.next_batch(timeout=5.0)
    assert time.perf_counter() - t0 < 0.4, \
        "full batch must ship immediately, not wait for the deadline"
    assert out.true_b == 4 and [s[0] for s in out.slots] == [0, 1, 2, 3]
    out2 = mb.next_batch(timeout=5.0)   # deadline flush of the rest
    assert out2.true_b == 2 and [s[0] for s in out2.slots] == [4, 5]


@deadline(30)
def test_batcher_request_groups_stay_atomic():
    mb = MicroBatcher(BatcherConfig(max_batch=4, max_wait_ms=1.0))
    mb.submit(_imgs(3), _keys(3), slot="a")
    mb.submit(_imgs(2), _keys(2), slot="b")
    out = mb.next_batch(timeout=5.0)
    assert [s[0] for s in out.slots] == ["a"], \
        "a 2-image group must not split to top up a 3-image batch"
    out2 = mb.next_batch(timeout=5.0)
    assert [s[0] for s in out2.slots] == ["b"]


@deadline(30)
def test_batcher_expired_deadline_promotes_starved_class():
    """Aging regression: interactive traffic alone fills max_batch
    every cycle, but a bulk entry whose deadline has expired must be
    PROMOTED into the next batch (head of the pop order), not merely
    trigger shipping while never being included."""
    mb = MicroBatcher(BatcherConfig(
        max_batch=2, max_wait_ms=5.0,
        classes={"interactive": 10_000.0, "bulk": 10.0}))
    mb.submit(_imgs(1, seed=9), _keys(1), slot="bulk0", priority="bulk")
    time.sleep(0.03)                    # bulk deadline (10ms) expires
    for i in range(4):                  # enough to fill 2 full batches
        mb.submit(_imgs(1, seed=i), _keys(1), slot=f"i{i}",
                  priority="interactive")
    out = mb.next_batch(timeout=5.0)
    assert out.slots[0][0] == "bulk0", \
        "expired bulk entry was not promoted ahead of interactive"
    assert [s[0] for s in out.slots] == ["bulk0", "i0"]
    # fresh traffic still pops in priority order afterwards
    assert [s[0] for s in mb.next_batch(timeout=5.0).slots] \
        == ["i1", "i2"]


@deadline(30)
def test_batcher_priority_order_without_expiry():
    """With no expired deadlines, priority popping is unchanged:
    interactive preempts an earlier-queued (but unexpired) bulk entry,
    and bulk backfills remaining capacity."""
    mb = MicroBatcher(BatcherConfig(
        max_batch=4, max_wait_ms=5.0,
        classes={"interactive": 10_000.0, "bulk": 10_000.0}))
    mb.submit(_imgs(2, seed=9), _keys(2), slot="bulk0", priority="bulk")
    mb.submit(_imgs(2, seed=1), _keys(2), slot="i0",
              priority="interactive")
    out = mb.next_batch(timeout=5.0)
    assert [s[0] for s in out.slots] == ["i0", "bulk0"]


@deadline(30)
def test_batcher_admission_backpressure_under_slow_consumer():
    """Nobody drains the queue: admission must reject at the depth
    bound (backpressure, not OOM) and resume once space frees."""
    mb = MicroBatcher(BatcherConfig(max_batch=4, max_queue=4,
                                    max_wait_ms=1.0))
    for i in range(4):
        mb.submit(_imgs(1, seed=i), _keys(1), slot=i)
    with pytest.raises(AdmissionError, match="queue full"):
        mb.submit(_imgs(1), _keys(1), slot=99)
    assert mb.depth() == 4
    # block=True parks the submitter until the consumer catches up
    done = []

    def blocked_submit():
        mb.submit(_imgs(1), _keys(1), slot="late", block=True,
                  timeout=10.0)
        done.append(True)

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    time.sleep(0.05)
    assert not done, "blocked submitter admitted past the depth bound"
    assert mb.next_batch(timeout=5.0) is not None    # consumer drains
    t.join(10.0)
    assert done and mb.depth() == 1


# ---------------------------------------------------------------------------
# DetectionServer: online == offline, bit for bit
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_params():
    return init_extractor(jax.random.key(0),
                          n_bits=DEFAULT_CODE.codeword_bits,
                          channels=8, depth=2)


def _cfg(**kw):
    base = dict(tile=16, img_size=32, resize_src=40, mode="qrmark",
                rs_mode="device")
    base.update(kw)
    return DetectionConfig(**base)


_FIELDS = ("message_bits", "ok", "n_corrected", "logits")


def _online_trial(params, *, seed, max_batch, bucket, lanes,
                  max_wait_ms, n_requests=10):
    """Submit a random request stream (random group sizes + arrival
    jitter) online; compare each result against detect_batch of the
    same images with the same key on a fresh offline pipeline."""
    rng = np.random.default_rng(seed)
    reqs = [rng.integers(0, 256, (int(rng.integers(1, 5)), 64, 64, 3),
                         dtype=np.uint8) for _ in range(n_requests)]
    keys = [jax.random.key(int(rng.integers(0, 2**31)))
            for _ in range(n_requests)]
    srv = DetectionServer(
        _cfg(), params,
        batcher=BatcherConfig(max_batch=max_batch, max_wait_ms=max_wait_ms,
                              bucket=bucket),
        lanes=lanes).start()
    try:
        handles = []
        for r, k in zip(reqs, keys):
            handles.append(srv.submit(r, key=k))
            if rng.random() < 0.5:      # random arrival interleaving
                time.sleep(float(rng.uniform(0, 0.01)))
        results = [h.result(300) for h in handles]
    finally:
        srv.close()
    pipe = DetectionPipeline(_cfg(), params)
    for i, (r, k, res) in enumerate(zip(reqs, keys, results)):
        ref = pipe.detect_batch(r, key=k)
        for f in _FIELDS:
            np.testing.assert_array_equal(
                ref[f], res[f],
                err_msg=f"trial seed={seed} request {i} field {f}: "
                        f"online != detect_batch")


@deadline(420)
def test_online_bit_identity_random_interleavings(tiny_params):
    """The acceptance anchor: for random arrival orders, group sizes,
    bucket sizes, and lane configs, DetectionServer results are bitwise
    equal to DetectionPipeline.detect_batch on the qrmark/device path."""
    trials = [
        dict(seed=1, max_batch=8, bucket=0, max_wait_ms=3.0,
             lanes={"ingest": 1, "decode": 3, "rs": 2}),
        dict(seed=2, max_batch=5, bucket=3, max_wait_ms=1.0,
             lanes={"ingest": 1, "decode": 1, "rs": 1}),
    ]
    for t in trials:
        _online_trial(tiny_params, **t)


@deadline(300)
def test_online_straggler_retry_keeps_results_exact(tiny_params):
    """An absurdly aggressive straggler policy forces speculative
    re-execution of nearly every micro-batch; first-completion-wins
    plus pure stage fns must keep results bitwise correct."""
    srv = DetectionServer(
        _cfg(), tiny_params,
        batcher=BatcherConfig(max_batch=4, max_wait_ms=1.0),
        straggler_policy=StragglerPolicy(timeout_factor=0.0,
                                         min_timeout_s=0.001,
                                         max_retries=2),
        watchdog_interval_s=0.005).start()
    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, 256, (2, 64, 64, 3), dtype=np.uint8)
            for _ in range(6)]
    keys = [jax.random.key(50 + i) for i in range(6)]
    try:
        handles = [srv.submit(r, key=k) for r, k in zip(reqs, keys)]
        results = [h.result(120) for h in handles]
        retries = srv.mon.retry_count
    finally:
        srv.close()
    assert retries > 0, "the watchdog never re-issued a straggler"
    pipe = DetectionPipeline(_cfg(), tiny_params)
    for r, k, res in zip(reqs, keys, results):
        ref = pipe.detect_batch(r, key=k)
        for f in _FIELDS:
            np.testing.assert_array_equal(ref[f], res[f])


@deadline(300)
def test_online_live_reallocation_mid_traffic(tiny_params):
    """reallocate() applies Algorithm 1 on measured stage latencies to
    the RUNNING executor; traffic before and after stays correct."""
    srv = DetectionServer(
        _cfg(), tiny_params,
        batcher=BatcherConfig(max_batch=4, max_wait_ms=1.0),
        lanes={"ingest": 1, "decode": 1, "rs": 1}).start()
    rng = np.random.default_rng(4)
    reqs = [rng.integers(0, 256, (2, 64, 64, 3), dtype=np.uint8)
            for _ in range(8)]
    keys = [jax.random.key(80 + i) for i in range(8)]
    try:
        first = [srv.submit(r, key=k)
                 for r, k in zip(reqs[:4], keys[:4])]
        [h.result(120) for h in first]
        assert srv.drain(60)
        applied = srv.reallocate(lane_budget=6)
        assert applied is not None
        assert sum(applied.values()) <= 6
        assert srv.lane_counts() == applied
        second = [srv.submit(r, key=k)
                  for r, k in zip(reqs[4:], keys[4:])]
        results = [h.result(120) for h in second]
    finally:
        srv.close()
    pipe = DetectionPipeline(_cfg(), tiny_params)
    for r, k, res in zip(reqs[4:], keys[4:], results):
        ref = pipe.detect_batch(r, key=k)
        for f in _FIELDS:
            np.testing.assert_array_equal(ref[f], res[f])


@deadline(300)
def test_server_close_never_leaves_unresolved_futures(tiny_params):
    """Shutdown guarantee: every admitted request's handle resolves —
    with a result (drained before close) or a rejection — never a
    future that blocks forever.  Covers the executor-close callback
    path and the batcher flush of never-dispatched requests."""
    srv = DetectionServer(
        _cfg(), tiny_params,
        batcher=BatcherConfig(max_batch=4, max_wait_ms=200.0)).start()
    rng = np.random.default_rng(9)
    handles = [srv.submit(rng.integers(0, 256, (1, 64, 64, 3),
                                       dtype=np.uint8),
                          key=jax.random.key(i)) for i in range(5)]
    srv.close()          # immediately, with requests possibly queued
    for h in handles:
        assert h.done() or h._ready.wait(5), \
            "close() left a request future unresolved"
        try:
            res = h.result(0)
            assert res["message_bits"].shape[0] == 1
        except RuntimeError:
            pass         # rejected at shutdown: also a resolution


@deadline(120)
def test_server_rejects_empty_request(tiny_params):
    srv = DetectionServer(_cfg(), tiny_params).start()
    try:
        with pytest.raises(AdmissionError):
            srv.submit(np.zeros((0, 64, 64, 3), np.uint8))
        assert srv.metrics.counter("requests_rejected") == 1
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_percentiles_and_snapshot():
    m = MetricsRegistry()
    for v in range(1, 101):
        m.observe("lat", v / 1000.0)
    m.count("requests_completed", 100)
    m.count("images_completed", 100)
    snap = m.snapshot()
    assert snap["lat"]["n"] == 100
    assert snap["lat"]["p50"] == pytest.approx(0.050, abs=0.002)
    assert snap["lat"]["p95"] == pytest.approx(0.095, abs=0.002)
    assert snap["lat"]["p99"] == pytest.approx(0.099, abs=0.002)
    assert snap["throughput_rps"] > 0
    m.reset()
    snap2 = m.snapshot()
    assert "lat" not in snap2 and not snap2["counters"]
    assert percentile([], 50) != percentile([], 50)   # NaN on empty


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(sizes=st.lists(st.integers(1, 4), min_size=1, max_size=6),
           bucket=st.sampled_from([0, 2, 3]))
    def test_batcher_slicing_covers_every_request(sizes, bucket):
        """Property: coalesced slots tile [0, true_b) exactly, padding
        never leaks into a slot, for any group sizes and bucket."""
        mb = MicroBatcher(BatcherConfig(max_batch=16, max_wait_ms=0.5,
                                        bucket=bucket))
        for i, n in enumerate(sizes):
            mb.submit(_imgs(n, seed=i), _keys(n), slot=i)
        covered = []
        while sum(len(c) for c in covered) < len(sizes):
            out = mb.next_batch(timeout=2.0)
            assert out is not None
            off = 0
            for slot, o, n in out.slots:
                assert o == off and n == sizes[slot]
                off += n
            assert off == out.true_b <= out.padded_b
            covered.append(out.slots)
else:                                                  # pragma: no cover
    def test_batcher_slicing_covers_every_request():
        rng = np.random.default_rng(11)
        for trial in range(10):
            sizes = list(rng.integers(1, 5,
                                      size=int(rng.integers(1, 7))))
            bucket = int(rng.choice([0, 2, 3]))
            mb = MicroBatcher(BatcherConfig(max_batch=16,
                                            max_wait_ms=0.5,
                                            bucket=bucket))
            for i, n in enumerate(sizes):
                mb.submit(_imgs(int(n), seed=i), _keys(int(n)), slot=i)
            seen = 0
            while seen < len(sizes):
                out = mb.next_batch(timeout=2.0)
                assert out is not None
                off = 0
                for slot, o, n in out.slots:
                    assert o == off and n == sizes[slot]
                    off += n
                assert off == out.true_b <= out.padded_b
                seen += len(out.slots)
