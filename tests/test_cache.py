"""Content-addressed result cache, dedup-in-flight, and SLO-tiered
admission tests (serving.cache + the DetectionServer integration).

The exactness bar: a tier-1 cache hit must be BITWISE the cold-path
result.  That only holds because keyless requests switch to
content-derived fold_in keys (identical pixels -> identical keys), so
the tests cross-check served results against the offline engines
(``detect_batch`` / sharded ``run_batch``) at the same content key —
the RNG-key contract every engine shares.

Property-based tests run when ``hypothesis`` is installed; seeded
equivalents always run (same pattern as test_rs.py).  Server tests
wear the deadlock canary (tests/canary.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from canary import deadline
from repro.core import tiling
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.extractor import (encoder_forward, init_encoder,
                                  init_extractor)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.data.pipeline import synth_image
from repro.serving import (AdmissionError, BatcherConfig,
                           DetectionServer, EmbeddingCache,
                           InFlightTable, ResultCache)
from repro.serving import cache as cache_lib

_FIELDS = ("message_bits", "ok", "n_corrected", "logits")


def _img(seed, h=40, w=40):
    return np.random.default_rng(seed).integers(
        0, 256, (h, w, 3), np.uint8)


# ---------------------------------------------------------------------------
# content digests (exact tier) + perceptual-hash utilities
# ---------------------------------------------------------------------------


def test_resize_mean_exact_block_means():
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 255, (32, 48))
    out = cache_lib._resize_mean(x, 8, 8)
    ref = x.reshape(8, 4, 8, 6).mean(axis=(1, 3))
    np.testing.assert_allclose(out, ref, rtol=1e-12)
    # non-divisible shapes still cover every pixel exactly once
    out = cache_lib._resize_mean(x, 5, 7)
    assert out.shape == (5, 7)
    np.testing.assert_allclose(cache_lib._resize_mean(x, 1, 1)[0, 0],
                               x.mean(), rtol=1e-12)


def test_resize_mean_clamps_to_tiny_inputs():
    """Images smaller than the requested grid must not produce
    zero-area blocks (division by zero -> NaN hash bits); the grid
    clamps to the input shape instead."""
    x = np.arange(12, dtype=np.float64).reshape(4, 3)
    with np.errstate(divide="raise", invalid="raise"):
        out = cache_lib._resize_mean(x, 8, 9)
        assert out.shape == (4, 3)
        np.testing.assert_allclose(out, x)
        # the phash utilities survive tiny images too, warning-free
        img = np.zeros((4, 3, 3), np.uint8)
        assert cache_lib.dhash(img) == 0
        assert np.isfinite(
            cache_lib._resize_mean(np.zeros((2, 2)), 8, 8)).all()


def test_image_digest_is_collision_free_on_flat_images():
    """Regression: the exact tier once keyed on dHash+aHash, under
    which ALL flat images of a shape collided (solid black == solid
    white) and a 'hit' could serve a different image's verdict.  The
    sha256 digest must separate any pixel-level difference."""
    black = np.zeros((32, 32, 3), np.uint8)
    white = np.full((32, 32, 3), 255, np.uint8)
    grey = np.full((32, 32, 3), 128, np.uint8)
    ds = {cache_lib.image_digest(x) for x in (black, white, grey)}
    assert len(ds) == 3
    # a single-pixel flip moves the digest
    tweaked = black.copy()
    tweaked[7, 9, 1] = 1
    assert cache_lib.image_digest(tweaked) != cache_lib.image_digest(black)


def _check_digest_invariants(img):
    d = cache_lib.image_digest(img)
    # identical resubmission (fresh buffer, same pixels)
    assert cache_lib.image_digest(np.array(img, copy=True)) == d
    # no-op re-encode: uint8 -> float -> uint8 is exact
    assert cache_lib.image_digest(
        img.astype(np.float32).astype(np.uint8)) == d
    assert cache_lib.image_digest(img.astype(np.float64)) == d


def test_digest_invariants_seeded():
    for seed in range(20):
        rng = np.random.default_rng(seed)
        _check_digest_invariants(rng.integers(
            0, 256, (int(rng.integers(8, 80)), int(rng.integers(8, 80)),
                     3), np.uint8))


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**32 - 1), st.integers(8, 80),
           st.integers(8, 80))
    def test_digest_invariants_hypothesis(seed, h, w):
        _check_digest_invariants(
            np.random.default_rng(seed).integers(0, 256, (h, w, 3),
                                                 np.uint8))


def test_request_digest_order_and_shape_sensitivity():
    a, b = _img(1), _img(2)
    d_ab = cache_lib.request_digest(np.stack([a, b]))
    assert d_ab == cache_lib.request_digest(np.stack([a, b]).copy())
    assert d_ab != cache_lib.request_digest(np.stack([b, a]))
    # true resolution is part of the digest even at equal hash grids
    small = _img(1, 16, 16)
    big = np.repeat(np.repeat(small, 2, 0), 2, 1)
    assert cache_lib.image_digest(small) != cache_lib.image_digest(big)


def test_result_key_binds_key_material():
    d = cache_lib.image_digest(_img(3))
    k1 = cache_lib.result_key(jax.random.key(1), d)
    k2 = cache_lib.result_key(jax.random.key(2), d)
    assert k1 != k2
    assert k1 == cache_lib.result_key(jax.random.key(1), d)
    assert k1.endswith(d)


# ---------------------------------------------------------------------------
# cache primitives
# ---------------------------------------------------------------------------


def test_result_cache_lru_and_buffer_isolation():
    c = ResultCache(capacity=2)
    r = {"ok": np.array([True]), "logits": np.zeros((1, 4))}
    c.put(b"a", r)
    r["logits"][:] = 9.0             # caller mutates after put
    hit = c.get(b"a")
    assert hit["logits"].sum() == 0.0, "cache aliased caller buffer"
    hit["logits"][:] = 5.0           # caller mutates a hit
    assert c.get(b"a")["logits"].sum() == 0.0
    c.put(b"b", r)
    assert c.get(b"a") is not None   # touch a -> b is now LRU
    c.put(b"c", r)
    assert c.get(b"b") is None and len(c) == 2
    assert c.get(b"a") is not None and c.get(b"c") is not None
    with pytest.raises(ValueError):
        ResultCache(capacity=0)


def test_embedding_cache_threshold_and_degenerates():
    c = EmbeddingCache(capacity=2, threshold=0.9)
    rows = {"ok": np.array(True)}
    c.put(np.array([2.0, 0.0]), rows)          # normalized on insert
    assert c.get(np.array([7.0, 0.0])) is not None     # cosine 1.0
    assert c.get(np.array([1.0, 1.0])) is None         # cos ~= 0.707
    assert c.get(np.array([0.9, 0.1])) is not None     # above 0.9
    assert c.get(np.zeros(2)) is None          # degenerate probe
    c.put(np.zeros(2), rows)                   # degenerate insert: no-op
    assert len(c) == 1
    c.put(np.array([0.0, 1.0]), rows)
    c.put(np.array([1.0, 1.0]), rows)          # capacity 2: oldest out
    assert len(c) == 2 and c.get(np.array([5.0, 0.0])) is None
    with pytest.raises(ValueError):
        EmbeddingCache(threshold=0.0)


def test_inflight_attach_pop_exactly_once():
    t = InFlightTable()
    assert t.attach(b"k", "L") is False        # leader
    assert t.attach(b"k", "f1") is True
    assert t.attach(b"k", "f2") is True
    assert t.depth() == 2
    assert t.pop(b"k") == ["f1", "f2"]
    assert t.pop(b"k") == []                   # exactly-once
    assert t.pop(None) == []
    assert t.attach(b"k", "L2") is False       # key free again


def test_config_validation():
    params = init_extractor(jax.random.key(0), n_bits=60, channels=4,
                            depth=1)
    with pytest.raises(ValueError, match="threshold"):
        DetectionPipeline(DetectionConfig(
            tile=16, img_size=32, cache_embedding_threshold=1.5), params)
    with pytest.raises(ValueError, match="capacit"):
        DetectionPipeline(DetectionConfig(
            tile=16, img_size=32, cache_capacity=0), params)


# ---------------------------------------------------------------------------
# DetectionServer: exact tier + dedup-in-flight
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_params():
    return init_extractor(jax.random.key(0),
                          n_bits=DEFAULT_CODE.codeword_bits,
                          channels=8, depth=2)


def _cfg(**kw):
    base = dict(tile=16, img_size=32, resize_src=40, mode="qrmark",
                rs_mode="device")
    base.update(kw)
    return DetectionConfig(**base)


@pytest.fixture(scope="module")
def exact_srv(tiny_params):
    srv = DetectionServer(
        _cfg(cache_exact=True, cache_capacity=32), tiny_params,
        batcher=BatcherConfig(max_batch=4, max_wait_ms=40.0,
                              classes={"interactive": 40.0,
                                       "bulk": 400.0}))
    srv.warmup(_img(0, 48, 48))
    srv.start()
    yield srv
    srv.close()


@deadline(120)
def test_exact_hit_bitwise_equals_cold_path_engines(exact_srv,
                                                    tiny_params):
    """Cold result == cache hit == detect_batch == sharded run_batch,
    all at the shared content-derived key — the four-engine RNG
    contract (the served cold path itself is the lane-executor
    engine)."""
    imgs = np.stack([_img(10, 48, 48), _img(11, 48, 48)])
    m0 = exact_srv.metrics.counter("cache_miss")
    h0 = exact_srv.metrics.counter("cache_hit_exact")
    cold = exact_srv.submit(imgs).result(60)
    hit = exact_srv.submit(np.array(imgs, copy=True)).result(60)
    assert exact_srv.metrics.counter("cache_miss") == m0 + 1
    assert exact_srv.metrics.counter("cache_hit_exact") == h0 + 1
    ckey = exact_srv.content_key(imgs)
    pipe = DetectionPipeline(_cfg(), tiny_params)
    offline = pipe.detect_batch(imgs, key=ckey)
    sharded = pipe.run_batch(imgs, key=ckey)
    pipe.close()
    for f in _FIELDS:
        np.testing.assert_array_equal(np.asarray(cold[f]),
                                      np.asarray(hit[f]), err_msg=f)
        np.testing.assert_array_equal(np.asarray(cold[f]),
                                      np.asarray(offline[f]), err_msg=f)
        np.testing.assert_array_equal(np.asarray(cold[f]),
                                      np.asarray(sharded[f]), err_msg=f)


@deadline(120)
def test_explicit_key_traffic_caches_too(exact_srv):
    imgs = _img(20, 48, 48)[None]
    key = jax.random.key(77)
    h0 = exact_srv.metrics.counter("cache_hit_exact")
    r1 = exact_srv.submit(imgs, key=key).result(60)
    r2 = exact_srv.submit(imgs, key=key).result(60)
    assert exact_srv.metrics.counter("cache_hit_exact") == h0 + 1
    for f in _FIELDS:
        np.testing.assert_array_equal(r1[f], r2[f], err_msg=f)
    # a different key is a different computation: no false hit
    h1 = exact_srv.metrics.counter("cache_hit_exact")
    exact_srv.submit(imgs, key=jax.random.key(78)).result(60)
    assert exact_srv.metrics.counter("cache_hit_exact") == h1


@deadline(120)
def test_dedup_in_flight_resolves_every_follower_once(exact_srv):
    """Concurrent identical requests coalesce onto one execution and
    every coalesced handle resolves exactly once (the 40ms batching
    deadline holds the leader queued while followers attach)."""
    imgs = np.stack([_img(30, 48, 48)])
    d0 = exact_srv.metrics.counter("dedup_coalesced")
    c0 = exact_srv.metrics.counter("requests_completed")
    handles = [exact_srv.submit(np.array(imgs, copy=True))
               for _ in range(3)]
    results = [h.result(60) for h in handles]
    assert all(h.done() for h in handles)
    assert exact_srv.metrics.counter("dedup_coalesced") == d0 + 2
    assert exact_srv.metrics.counter("requests_completed") == c0 + 3
    for f in _FIELDS:
        for r in results[1:]:
            np.testing.assert_array_equal(results[0][f], r[f],
                                          err_msg=f)
    assert exact_srv._dedup.depth() == 0


@deadline(120)
def test_priority_classes_and_rejected_accounting(exact_srv):
    """Unknown classes are AdmissionErrors counted as rejections (not
    failures), per-class latency distributions appear, and the
    registry derives rejection_rate."""
    with pytest.raises(AdmissionError, match="unknown priority"):
        exact_srv.submit(_img(40)[None], priority="nope")
    r0 = exact_srv.metrics.counter("requests_rejected")
    f0 = exact_srv.metrics.counter("requests_failed")
    exact_srv.submit(_img(41, 48, 48)[None],
                     priority="bulk").result(60)
    with pytest.raises(AdmissionError):
        exact_srv.submit(_img(42)[None], priority="also-nope")
    st = exact_srv.stats()
    assert st["counters"]["requests_rejected"] >= r0 + 1
    assert st["counters"].get("requests_failed", 0.0) == f0
    assert "request_latency_bulk_s" in st
    assert "request_latency_interactive_s" in st
    assert 0.0 < st["rejection_rate"] < 1.0
    c = st["counters"]
    hits = c.get("cache_hit_exact", 0) + c.get("dedup_coalesced", 0)
    lookups = hits + c.get("cache_miss", 0)
    assert st["cache_hit_rate"] == pytest.approx(
        hits / lookups if lookups else 0.0)


@deadline(60)
def test_close_rejects_coalesced_followers(tiny_params):
    """Exactly-once under executor close(): an un-started server's
    queued leader AND its coalesced followers are all rejected — no
    handle is ever left unresolved."""
    srv = DetectionServer(
        _cfg(cache_exact=True), tiny_params,
        batcher=BatcherConfig(max_batch=4, max_wait_ms=5000.0))
    imgs = _img(50)[None]
    leader = srv.submit(imgs)
    follower = srv.submit(np.array(imgs, copy=True))
    assert srv.metrics.counter("dedup_coalesced") == 1
    srv.close()
    for h in (leader, follower):
        with pytest.raises(RuntimeError, match="closed"):
            h.result(1)
    assert srv.metrics.counter("requests_failed") == 2
    assert srv._finished == srv._admitted


# ---------------------------------------------------------------------------
# tier 2: near-duplicate embedding cache on the margined workload
# ---------------------------------------------------------------------------

TILE, IMG, B = 16, 48, 2


@pytest.fixture(scope="module")
def workload():
    """Two watermark payloads on the corr-margined detector (the fig12
    workload): tied pattern bank, zeroed conv head, so embeddings and
    logits carry real watermark structure without trained artifacts."""
    code = DEFAULT_CODE
    enc = init_encoder(jax.random.key(1), n_bits=code.codeword_bits,
                       channels=8, depth=2, tile=TILE)
    dec = init_extractor(jax.random.key(2), n_bits=code.codeword_bits,
                         channels=8, depth=2, tile=TILE,
                         patterns=enc["patterns"])
    dec["head"]["w"] = dec["head"]["w"] * 0.0   # corr path only
    rng = np.random.default_rng(0)

    def embed(msg, seeds):
        cw = jnp.asarray(rs_encode(code, msg))
        imgs = jnp.asarray(np.stack([synth_image(s, IMG) for s in seeds]),
                           jnp.float32) / 127.5 - 1.0
        flat = tiling.grid_partition(imgs, TILE).reshape(-1, TILE, TILE, 3)
        xw, _ = encoder_forward(
            enc, flat, jnp.broadcast_to(cw, (flat.shape[0],
                                             code.codeword_bits)),
            embed_rms=0.2)
        g = IMG // TILE
        xw = xw.reshape(len(seeds), g, g, TILE, TILE, 3).transpose(
            0, 1, 3, 2, 4, 5).reshape(len(seeds), IMG, IMG, 3)
        return np.asarray((xw + 1.0) * 127.5, np.float32)

    msg_a = rng.integers(0, 2, code.message_bits)
    msg_b = 1 - msg_a
    return {"dec": dec,
            "raw_a": embed(msg_a, range(B)),
            "raw_b": embed(msg_b, range(100, 100 + B))}


def _wcfg(**kw):
    base = dict(tile=TILE, img_size=IMG, resize_src=IMG, mode="qrmark",
                rs_mode="device", code=DEFAULT_CODE)
    base.update(kw)
    return DetectionConfig(**base)


def test_embed_emission_is_logit_inert_and_payloads_separate(workload):
    """decode_keyed_embed returns bitwise the decode_keyed logits plus
    a GAP embedding; across different watermark payloads those
    embeddings NEVER clear the tier-2 cosine threshold (the near-dup
    tier cannot leak one payload's verdict to another), while the same
    pixels reproduce cosine 1.0."""
    w = workload
    pipe = DetectionPipeline(_wcfg(), w["dec"])
    reg = pipe.stages
    key = jax.random.key(3)
    keys = reg.image_keys(key, B)
    cache = EmbeddingCache(capacity=16, threshold=0.995)
    embeds = {}
    for name in ("raw_a", "raw_b"):
        x = reg.ingest_keyed(w[name], keys)
        logits, emb = reg.decode_keyed_embed(x, keys)
        np.testing.assert_array_equal(
            np.asarray(logits), np.asarray(reg.decode_keyed(x, keys)),
            err_msg="embed emission changed the logits")
        embeds[name] = np.asarray(emb)
    pipe.close()
    for i in range(B):
        cache.put(embeds["raw_a"][i], {"ok": np.array(True), "i": i})
    for i in range(B):          # cross-payload: never fires
        assert cache.get(embeds["raw_b"][i]) is None, \
            "near-dup tier matched across watermark payloads"
    for i in range(B):          # same pixels: always fires
        assert cache.get(embeds["raw_a"][i].copy()) is not None


@deadline(900)
def test_server_embed_tier_short_circuits_escalation(workload):
    """Full server path: a thin-margin request escalates and settles;
    resubmitting the same pixels (same explicit key, exact tier OFF)
    hits the embedding tier at round 0 and adopts the settled verdict
    without burning new escalation rounds."""
    w = workload
    srv = DetectionServer(
        _wcfg(escalate_tiles=2, escalate_margin=50.0,
              cache_embedding_threshold=0.995), w["dec"],
        batcher=BatcherConfig(max_batch=B, max_wait_ms=5.0),
        watchdog_interval_s=10.0)
    srv.warmup(w["raw_a"][0])
    srv.start()
    try:
        key = jax.random.key(5)
        r1 = srv.submit(w["raw_a"], key=key).result(300)
        assert (r1["tiles_used"] > 1).all(), \
            "margin trigger did not escalate"
        assert r1["ok"].all()
        e0 = srv.metrics.counter("escalation_batches")
        r2 = srv.submit(np.array(w["raw_a"], copy=True),
                        key=key).result(300)
        assert srv.metrics.counter("cache_hit_embed") == B
        assert srv.metrics.counter("escalation_batches") == e0, \
            "embed hit should skip escalation entirely"
        assert (r2["tiles_used"] == 1).all()
        for f in _FIELDS:
            np.testing.assert_array_equal(r1[f], r2[f], err_msg=f)
    finally:
        srv.close()
