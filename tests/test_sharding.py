"""Sharding planner unit tests: divisibility fallbacks, spec validity on
a real (1-device) mesh, ZeRO-1 data sharding, and plan heuristics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES_BY_NAME, all_configs, reduced
from repro.launch.mesh import make_local_mesh
from repro.models import lm
from repro.sharding import planner

ARCHS = sorted(all_configs().keys())


class FakeMesh:
    """Shape-only mesh stand-in for spec computation tests."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH_1POD = FakeMesh({"data": 16, "model": 16})
MESH_2POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def fake_plan(cfg, shape, mesh, **kw):
    import repro.sharding.planner as pl
    return pl.make_plan(cfg, shape, mesh, **kw)


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["1pod", "2pod"])
def test_param_specs_are_divisible(arch, mesh):
    """Every sharded dim must actually divide by its mesh axes product."""
    cfg = all_configs()[arch]
    plan = fake_plan(cfg, SHAPES_BY_NAME["train_4k"], mesh)
    ap = lm.abstract_params(cfg)
    specs = planner.param_specs(cfg, ap, plan)
    leaves = jax.tree.leaves(ap)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        for d, s in enumerate(spec):
            if s is None:
                continue
            axes = s if isinstance(s, tuple) else (s,)
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[d] % prod == 0, \
                f"{arch}: dim {d} of {leaf.shape} not divisible by {axes}"


@pytest.mark.parametrize("arch", ["smollm-360m", "granite-moe-3b-a800m",
                                  "mamba2-2.7b"])
def test_zero1_opt_state_data_sharded(arch):
    cfg = all_configs()[arch]
    plan = fake_plan(cfg, SHAPES_BY_NAME["train_4k"], MESH_1POD)
    ap = lm.abstract_params(cfg)
    ospecs = planner.opt_specs(cfg, ap, plan)
    n_data_sharded = 0
    for leaf, spec in zip(jax.tree.leaves(ap),
                          jax.tree.leaves(ospecs,
                                          is_leaf=lambda x:
                                          isinstance(x, P))):
        used = [a for s in spec if s is not None
                for a in (s if isinstance(s, tuple) else (s,))]
        if any(a in plan.data_axes for a in used):
            n_data_sharded += 1
            for d, s in enumerate(spec):
                if s is None:
                    continue
                axes = s if isinstance(s, tuple) else (s,)
                prod = int(np.prod([MESH_1POD.shape[a] for a in axes]))
                assert leaf.shape[d] % prod == 0
    assert n_data_sharded > 0, "ZeRO-1 sharded nothing"


def test_fsdp_triggers_for_large_models():
    big = all_configs()["mistral-large-123b"]
    small = all_configs()["smollm-360m"]
    assert fake_plan(big, SHAPES_BY_NAME["train_4k"], MESH_1POD).fsdp
    assert not fake_plan(small, SHAPES_BY_NAME["train_4k"],
                         MESH_1POD).fsdp


def test_microbatching_scales_with_model():
    shape = SHAPES_BY_NAME["train_4k"]
    big = fake_plan(all_configs()["mistral-large-123b"], shape, MESH_1POD)
    small = fake_plan(all_configs()["smollm-360m"], shape, MESH_1POD)
    assert big.n_micro > small.n_micro
    assert shape.global_batch % big.n_micro == 0


def test_batch_not_divisible_falls_back_to_replicate():
    cfg = all_configs()["mamba2-2.7b"]
    shape = SHAPES_BY_NAME["long_500k"]  # global_batch=1
    plan = fake_plan(cfg, shape, MESH_1POD)
    specs = lm.input_specs(cfg, shape)
    sspec = planner.decode_state_specs(cfg, plan, specs["state"])
    for spec in jax.tree.leaves(sspec, is_leaf=lambda x: isinstance(x, P)):
        for s in spec:
            axes = s if isinstance(s, tuple) else ((s,) if s else ())
            assert "data" not in axes or True
    # batch dim (1) must never be sharded
    caches = jax.tree.leaves(specs["state"]["caches"])
    cspecs = jax.tree.leaves(sspec["caches"],
                             is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(caches, cspecs):
        if len(spec) > 1 and spec[1] is not None:
            axes = spec[1] if isinstance(spec[1], tuple) else (spec[1],)
            prod = int(np.prod([MESH_1POD.shape[a] for a in axes]))
            assert leaf.shape[1] % prod == 0


def test_specs_work_on_real_local_mesh():
    """jit with planner shardings must run on the actual (1-dev) mesh."""
    cfg = reduced(all_configs()["smollm-360m"])
    mesh = make_local_mesh()
    shape = SHAPES_BY_NAME["train_4k"]
    plan = planner.make_plan(cfg, shape, mesh)
    ap = lm.abstract_params(cfg)
    specs = planner.param_specs(cfg, ap, plan)
    sh = planner.to_shardings(specs, mesh)
    with mesh:
        params = jax.jit(lambda k: lm.init_params(cfg, k),
                         out_shardings=sh)(jax.random.key(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32)}
    loss = jax.jit(lambda p, b: lm.forward_train(p, b, cfg, remat=False))(
        params, batch)
    assert bool(jnp.isfinite(loss))
