"""Subprocess helper for tests/test_lanes.py: forces a 4-device CPU
topology (XLA_FLAGS must be set before jax initialises, hence the
separate process) and checks that the data-parallel sharded
``DetectionPipeline.run_batch`` is bit-identical to the single-device
path, including for a ragged batch that needs padding, and that the
tile-first fused ingest matches the staged full-image path on the
sharded mesh.

Not named test_*.py on purpose — pytest must not collect it.
"""
import dataclasses
import os
import sys
from pathlib import Path

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.detect import DetectionConfig, DetectionPipeline  # noqa: E402
from repro.core.extractor import init_extractor  # noqa: E402
from repro.core.rs.codec import DEFAULT_CODE  # noqa: E402
from repro.launch.mesh import make_detection_mesh  # noqa: E402


def main():
    devs = jax.devices()
    assert len(devs) == 4, f"expected 4 forced CPU devices, got {len(devs)}"
    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits,
                            channels=8, depth=2)
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="qrmark", rs_mode="device")
    rng = np.random.default_rng(0)

    mesh4 = make_detection_mesh(devs)
    mesh1 = make_detection_mesh(devs[:1])

    for b in (8, 6):  # divisible and ragged (6 -> padded to 8 on 4 devs)
        raw = rng.integers(0, 256, (b, 64, 64, 3), dtype=np.uint8)
        p_multi = DetectionPipeline(cfg, params)
        p_single = DetectionPipeline(cfg, params)
        out_m = p_multi.run_batch(raw, mesh=mesh4)
        out_s = p_single.run_batch(raw, mesh=mesh1)
        assert np.array_equal(out_m["message_bits"], out_s["message_bits"]), \
            f"b={b}: sharded message bits diverge"
        assert np.array_equal(out_m["ok"], out_s["ok"]), f"b={b}: ok diverge"
        assert np.array_equal(out_m["n_corrected"], out_s["n_corrected"])
        assert out_m["logits"].shape == (b, DEFAULT_CODE.codeword_bits)
        # decode is per-image, so sharding must not move the floats either
        assert np.array_equal(out_m["logits"], out_s["logits"]), \
            f"b={b}: logits diverge"

    # tile-first fused ingest == staged full-image ingest on the 4-device
    # mesh (cfg above runs tile-first by default; rerun staged and compare)
    assert DetectionPipeline(cfg, params).tile_first
    raw = rng.integers(0, 256, (8, 64, 64, 3), dtype=np.uint8)
    key = jax.random.key(11)
    cfg_staged = dataclasses.replace(cfg, tile_first=False)
    out_tf = DetectionPipeline(cfg, params).run_batch(
        raw, mesh=mesh4, key=key)
    out_st = DetectionPipeline(cfg_staged, params).run_batch(
        raw, mesh=mesh4, key=key)
    for f in ("message_bits", "ok", "n_corrected", "logits"):
        assert np.array_equal(out_tf[f], out_st[f]), \
            f"sharded tile-first vs staged: {f} diverges"
    print("OK")


if __name__ == "__main__":
    main()
