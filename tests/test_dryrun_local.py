"""Dry-run machinery integration test on the LOCAL mesh (1 device):
lower_cell + probes + roofline derivation for a reduced arch — proves the
code path end-to-end without the 512-device env (which the real dry-run
sets in its own process)."""
import dataclasses

import jax
import pytest

from repro.configs.base import ShapeSpec, all_configs, reduced
from repro.launch import dryrun, hlo_analysis
from repro.launch.mesh import make_local_mesh
from repro.sharding import planner


@pytest.mark.parametrize("mode", ["train", "prefill", "decode"])
def test_lower_compile_analyze_local(mode):
    cfg = reduced(all_configs()["smollm-360m"])
    shape = ShapeSpec("t", 64, 2, mode)
    mesh = make_local_mesh()
    plan = planner.make_plan(cfg, shape, mesh)
    lowered = dryrun.lower_cell(cfg, shape, mesh, plan)
    compiled = lowered.compile()
    rec = dryrun._analyze(compiled, plan.n_chips)
    assert rec["flops"] > 0
    assert rec["bytes"] > 0
    assert rec["memory"]["temp_size_in_bytes"] is not None


def test_probe_derivation_math():
    """A + ng*B reconstruction from the depth-1/2 probes."""
    cfg = reduced(all_configs()["smollm-360m"])
    shape = ShapeSpec("t", 64, 2, "prefill")
    mesh = make_local_mesh()
    plan = planner.make_plan(cfg, shape, mesh)
    rec = {"real": {}}
    rec["probe"] = dryrun._run_probes(cfg, shape, mesh, plan)
    d1, d2 = rec["probe"]["d1"], rec["probe"]["d2"]
    assert d2["flops"] > d1["flops"]  # one extra group costs flops
    derived = dryrun._derive_roofline(cfg, shape, mesh, plan, rec)
    # total >= the 2-layer probe's cost (ng=2 for reduced smollm)
    assert derived["flops_per_device"] >= d2["flops"] * 0.99
    assert derived["dominant"] in ("compute", "memory", "collective")


def test_collective_parser_formats():
    txt = """
  %ag = f32[64,512]{1,0} all-gather(%x), channel_id=1, replica_groups=[16,32]<=[512], dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%y), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = f32[32]{0} reduce-scatter(%w), replica_groups=[4,8]<=[32], dimensions={0}
"""
    st = hlo_analysis.collective_stats(txt)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "collective-permute": 1, "reduce-scatter": 1}
    # all-gather: output 64*512*4 bytes * (31/32)
    assert abs(st.bytes_by_kind["all-gather"]
               - 64 * 512 * 4 * 31 / 32) < 1.0
    # all-reduce over group of 4: 2*(3/4)*1024*2 bytes
    assert abs(st.bytes_by_kind["all-reduce"] - 2 * 0.75 * 2048) < 1.0
    # reduce-scatter: shard 32*4 bytes, n=8 -> (7/8)*32*4*8
    assert abs(st.bytes_by_kind["reduce-scatter"]
               - (7 / 8) * 32 * 4 * 8) < 1.0


def test_cell_skip_reasons_recorded(tmp_path):
    rec = dryrun.run_cell("mistral-large-123b", "long_500k", "single",
                          out_dir=tmp_path, probes=False)
    assert rec["status"] == "skipped"
    assert "full-attention" in rec["reason"]
