"""End-to-end detection pipeline tests: all modes, RS integration,
watermark recovery with a (tiny, briefly-trained) encoder/extractor pair,
and the statistical verification threshold."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.detect import (DetectionConfig, DetectionPipeline,
                               binomial_threshold, verify_against_key)
from repro.core.extractor import (encoder_forward, extractor_forward,
                                  init_encoder, init_extractor)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.core import losses, tiling
from repro.core.train_extractor import ExtractorTrainConfig, train


@pytest.fixture(scope="module")
def tiny_trained():
    """The trained tile-16 pair when the offline-stage artifact exists
    (examples/train_extractor.py), else a 90-step micro pair.  Returns
    (params, cfg, strong) — ``strong`` scales the accuracy thresholds."""
    import pickle
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "experiments" / \
        "extractor" / "tile16_params.pkl"
    if art.exists():
        with open(art, "rb") as f:
            d = pickle.load(f)
        return d["params"], d["cfg"], True
    cfg = ExtractorTrainConfig(steps=90, batch=16, tile=16, img_size=64,
                               channels=16, depth=3, enc_channels=12,
                               enc_depth=2, curriculum_frac=1.0)
    out = train(cfg, log_every=1000, verbose=False)
    return out["params"], cfg, False


def test_watermark_roundtrip_clean(tiny_trained):
    params, cfg, strong = tiny_trained
    code = cfg.code
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))
    # natural-statistics tiles (the training/deployment distribution) —
    # uniform white noise has full high-frequency energy and swamps the
    # spread-spectrum band by construction
    from repro.data.pipeline import synth_image
    n = 32
    imgs = jnp.asarray(np.stack([synth_image(i, 32)[:16, :16]
                                 for i in range(n)]),
                       jnp.float32) / 127.5 - 1.0
    xw, _ = encoder_forward(params["enc"], imgs,
                            jnp.broadcast_to(cw, (n, code.codeword_bits)))
    logits = extractor_forward(params["dec"], xw)
    acc = float(losses.bit_accuracy(
        logits, jnp.broadcast_to(cw, (n, code.codeword_bits))))
    # tile 16 is the paper's sub-capacity point (Table 2: 0.748 there,
    # 0.906 ours) — the clean floor reflects that, not >=32-tile quality
    floor = 0.85 if strong else 0.72
    assert acc > floor, f"pair only reached bit_acc {acc} (floor {floor})"


@pytest.mark.parametrize("mode,rs_mode", [
    ("sequential", "cpu_sync"),
    ("tiled", "cpu_pool"),
    ("qrmark", "device"),
    ("qrmark", "cpu_pool"),
])
def test_pipeline_modes_run(tiny_trained, mode, rs_mode):
    params, tcfg, _ = tiny_trained
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40, mode=mode,
                          rs_mode=rs_mode, rs_threads=2, code=tcfg.code)
    pipe = DetectionPipeline(cfg, params["dec"])
    try:
        raw = np.random.default_rng(0).integers(
            0, 256, (4, 64, 64, 3), dtype=np.uint8)
        out = pipe.detect_batch(jnp.asarray(raw))
        assert out["message_bits"].shape == (4, tcfg.code.message_bits)
        assert out["ok"].shape == (4,)
        # unwatermarked random images must NOT verify as watermarked
        key = np.random.default_rng(1).integers(
            0, 2, tcfg.code.message_bits)
        ver = verify_against_key(out["message_bits"], key)
        assert not ver.any()
    finally:
        pipe.close()


def test_run_stream_interleaved(tiny_trained):
    params, tcfg, _ = tiny_trained
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="qrmark", rs_mode="device",
                          interleave=True, code=tcfg.code)
    pipe = DetectionPipeline(cfg, params["dec"])
    raw = [np.random.default_rng(i).integers(0, 256, (4, 64, 64, 3),
                                             dtype=np.uint8)
           for i in range(3)]
    res = pipe.run_stream(raw)
    assert res["images"] == 12
    assert res["throughput_ips"] > 0


def test_verify_threshold_fpr():
    """The binomial threshold must reject random bits at ~the target FPR
    and accept near-perfect matches."""
    rng = np.random.default_rng(0)
    key = rng.integers(0, 2, 48)
    random_msgs = rng.integers(0, 2, (5000, 48))
    fp = verify_against_key(random_msgs, key, fpr=1e-6).mean()
    assert fp == 0.0  # 5000 trials at 1e-6 expected 0
    good = np.tile(key, (10, 1))
    good[:, 0] ^= 1  # one bit wrong
    assert verify_against_key(good, key, fpr=1e-6).all()


@pytest.mark.parametrize("n", [48, 60])
@pytest.mark.parametrize("fpr", [1e-3, 1e-6])
def test_binomial_threshold_tau(n, fpr):
    """tau must be the smallest integer with
    sum_{i >= tau} C(n, i) <= fpr * 2^n (exact integer arithmetic),
    and verify_against_key must switch exactly at that agreement."""
    from math import comb
    tail = 0
    tau_exp = n + 1
    for i in range(n, -1, -1):
        tail += comb(n, i)
        if tail * (1.0 / fpr) > 2 ** n:  # P[X >= i] > fpr
            break
        tau_exp = i
    assert binomial_threshold(n, fpr) == tau_exp
    # behavioral check: agreement == tau passes, tau - 1 fails
    key = np.zeros(n, np.int32)
    at_tau = np.zeros((1, n), np.int32)
    at_tau[0, : n - tau_exp] = 1          # agreement exactly tau
    below = np.zeros((1, n), np.int32)
    below[0, : n - tau_exp + 1] = 1       # agreement tau - 1
    assert verify_against_key(at_tau, key, fpr=fpr).all()
    assert not verify_against_key(below, key, fpr=fpr).any()


@pytest.mark.parametrize("n", [48, 60])
@pytest.mark.parametrize("fpr", [1e-3, 1e-6])
def test_binomial_threshold_cache_agrees_with_uncached(n, fpr):
    """The lru_cache wrapper must be a pure memo: cached and uncached
    values agree across the (n, fpr) grid, and repeated calls hit the
    cache instead of rebuilding the comb table."""
    from repro.core.detect import _binomial_threshold_uncached
    assert binomial_threshold(n, fpr) == \
        _binomial_threshold_uncached(n, fpr)
    before = binomial_threshold.cache_info().hits
    assert binomial_threshold(n, fpr) == \
        _binomial_threshold_uncached(n, fpr)
    assert binomial_threshold.cache_info().hits > before


def test_binomial_threshold_fails_closed_for_short_keys():
    """When even full agreement can't reach the target FPR (2^-n > fpr)
    the threshold must reject everything, not accept everything."""
    assert binomial_threshold(12, 1e-6) == 13
    key = np.zeros(12, np.int32)
    perfect = np.zeros((1, 12), np.int32)
    assert not verify_against_key(perfect, key, fpr=1e-6).any()
    # sanity: at n=48 full agreement still verifies
    assert binomial_threshold(48, 1e-6) <= 48


def test_tile_first_matches_staged_all_engines(tiny_trained):
    """The tile-first fused ingest must be bit-identical to the staged
    full-image path on every execution engine: the fused detect_batch,
    the lane executor at 1 and 4 lanes, and the sharded run_batch."""
    params, tcfg, _ = tiny_trained
    mk = lambda tf: DetectionConfig(
        tile=16, img_size=32, resize_src=40, mode="qrmark",
        rs_mode="device", code=tcfg.code, tile_first=tf)
    rng = np.random.default_rng(3)
    raw = rng.integers(0, 256, (5, 64, 64, 3), dtype=np.uint8)
    data = [rng.integers(0, 256, (4, 64, 64, 3), dtype=np.uint8)
            for _ in range(3)]

    def collect(results):
        return {k: np.concatenate([r[k] for r in results])
                for k in ("message_bits", "ok", "logits")}

    outs = {}
    for tf in (True, False):
        # one pipeline per variant: detect_batch/run_batch take explicit
        # keys and run_stream advances _seq identically in both variants,
        # so every engine sees the same key sequence
        pipe = DetectionPipeline(mk(tf), params["dec"])
        assert pipe.tile_first == tf
        outs[tf] = {
            "batch": pipe.detect_batch(raw, key=jax.random.key(1)),
            "sharded": pipe.run_batch(raw, key=jax.random.key(2)),
            "lanes1": collect(pipe.run_stream(data, lanes=1)["results"]),
            "lanes4": collect(pipe.run_stream(data, lanes=4)["results"]),
        }
    for engine in ("batch", "sharded", "lanes1", "lanes4"):
        for field in ("message_bits", "ok", "logits"):
            np.testing.assert_array_equal(
                outs[True][engine][field], outs[False][engine][field],
                err_msg=f"{engine}/{field} diverges tile-first vs staged")


def test_end_to_end_detection_of_watermarked_images(tiny_trained):
    """Embed a known key into synthetic images, push them through the
    full qrmark pipeline, and require RS-corrected exact recovery.
    Uses the tile-32 artifact when present: tile 16 sits below the RS
    capacity point (word acc 0 — paper Table 2 and ours), so exact
    recovery is only meaningful from tile 32 up."""
    import pickle
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "experiments" / \
        "extractor" / "tile32_params.pkl"
    if art.exists():
        with open(art, "rb") as f:
            d = pickle.load(f)
        params, tcfg, strong = d["params"], d["cfg"], True
    else:
        params, tcfg, strong = tiny_trained
    code = tcfg.code
    tile = tcfg.tile
    rng = np.random.default_rng(7)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))

    # build watermarked "uploads": tile-grid embed on 32x32 images with
    # natural statistics (see test_watermark_roundtrip_clean)
    from repro.data.pipeline import synth_image
    imgs = jnp.asarray(np.stack([synth_image(100 + i, 2 * tile)
                                 for i in range(6)]),
                       jnp.float32) / 127.5 - 1.0
    tiles = tiling.grid_partition(imgs, tile)  # (6, 4, t, t, 3)
    flat = tiles.reshape(-1, tile, tile, 3)
    cwb = jnp.broadcast_to(cw, (flat.shape[0], code.codeword_bits))
    xw_flat, _ = encoder_forward(params["enc"], flat, cwb)
    xw = xw_flat.reshape(6, 2, 2, tile, tile, 3).transpose(
        0, 1, 3, 2, 4, 5).reshape(6, 2 * tile, 2 * tile, 3)

    key = jax.random.key(3)
    sel, _ = tiling.select_tiles("random_grid", key, xw, tile)
    logits = extractor_forward(params["dec"], sel)
    bits = (logits > 0).astype(jnp.int32)
    from repro.core.rs import jax_rs
    dec = jax_rs.make_batch_decoder(code)(bits)
    ok = np.asarray(dec["ok"])
    rec = np.asarray(dec["message_bits"])
    good = ok & np.all(rec == msg[None, :], axis=1)
    floor = 0.5 if strong else 0.0
    raw_acc = float((np.asarray(bits) == np.asarray(cw)[None, :]).mean())
    assert raw_acc > 0.7, f"raw tile bit acc {raw_acc}"
    assert good.mean() >= floor, f"recovered only {good.mean():.2f}"
