"""Deadlock canary for service-mode executor/server/fleet tests.

``@deadline(seconds)`` runs the test body in a worker thread and FAILS
(instead of hanging the whole suite) if it does not finish in time —
the failure mode of a queue/lock bug in the long-lived executor, the
DetectionServer, or the fleet router (spill-over loops, drain-during-
reconfigure, crash-during-drain) is a silent deadlock, which a plain
test would turn into a CI timeout with no traceback.  (pytest-timeout
is not in the container; this is the dependency-free equivalent,
registered as the ``deadline`` marker in pytest.ini for bookkeeping.)

On timeout the canary dumps the stack of every live thread into the
failure message — for the router paths the wedged frame (a blocking
``submit`` on a dispatcher thread, a drain that can never complete)
is the whole diagnosis, and without the dump a hang reproduced only
in CI is undebuggable.

Not named test_*.py on purpose — pytest must not collect it.
"""
from __future__ import annotations

import functools
import sys
import threading
import traceback

import pytest


def _thread_dump() -> str:
    """One formatted stack per live thread (the post-mortem a wedged
    executor/router hang needs; daemon pump/watchdog threads included)."""
    frames = sys._current_frames()
    by_id = {t.ident: t for t in threading.enumerate()}
    out = []
    for tid, frame in frames.items():
        t = by_id.get(tid)
        name = t.name if t is not None else f"thread-{tid}"
        stack = "".join(traceback.format_stack(frame))
        out.append(f"--- {name} ---\n{stack}")
    return "\n".join(out)


def deadline(seconds: float):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            err = []

            def run():
                try:
                    fn(*args, **kwargs)
                except BaseException as e:   # re-raised on the test thread
                    err.append(e)

            t = threading.Thread(target=run, daemon=True,
                                 name=f"deadline/{fn.__name__}")
            t.start()
            t.join(seconds)
            if t.is_alive():
                pytest.fail(f"deadlock canary: {fn.__name__} still "
                            f"running after {seconds}s\n\nlive thread "
                            f"stacks:\n{_thread_dump()}")
            if err:
                raise err[0]
        return pytest.mark.deadline(wrapper)
    return deco
