"""Deadlock canary for service-mode executor/server tests.

``@deadline(seconds)`` runs the test body in a worker thread and FAILS
(instead of hanging the whole suite) if it does not finish in time —
the failure mode of a queue/lock bug in the long-lived executor is a
silent deadlock, which a plain test would turn into a CI timeout with
no traceback.  (pytest-timeout is not in the container; this is the
dependency-free equivalent, registered as the ``deadline`` marker in
pytest.ini for bookkeeping.)

Not named test_*.py on purpose — pytest must not collect it.
"""
from __future__ import annotations

import functools
import threading

import pytest


def deadline(seconds: float):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            err = []

            def run():
                try:
                    fn(*args, **kwargs)
                except BaseException as e:   # re-raised on the test thread
                    err.append(e)

            t = threading.Thread(target=run, daemon=True,
                                 name=f"deadline/{fn.__name__}")
            t.start()
            t.join(seconds)
            if t.is_alive():
                pytest.fail(f"deadlock canary: {fn.__name__} still "
                            f"running after {seconds}s")
            if err:
                raise err[0]
        return pytest.mark.deadline(wrapper)
    return deco
