import os
import sys
from pathlib import Path

# Tests run against a single CPU device (the dry-run sets its own 512
# placeholder devices in a separate process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
