"""QRMark algorithm-level tests: RS-aware loss semantics, transforms,
LDM decoder fine-tuning (§4.2), and the tile-size predictor (App B.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses, transforms
from repro.core.rs.codec import DEFAULT_CODE


# ---------------------------------------------------------------------------
# RS-aware loss (§4.1)
# ---------------------------------------------------------------------------


def _logits_with_errors(msg, n_err, margin=8.0):
    """Confident logits agreeing with msg except n_err flipped bits."""
    pm = 2.0 * msg - 1.0
    lg = margin * pm
    lg = lg.at[:, :n_err].multiply(-1.0)
    return lg


def test_rs_aware_loss_free_within_capacity():
    code = DEFAULT_CODE
    msg = jnp.asarray(np.random.default_rng(0).integers(
        0, 2, (4, code.codeword_bits)), jnp.float32)
    # errors within one symbol (<= t=1 symbol errors): loss ~ 0
    lg_ok = _logits_with_errors(msg, code.m)  # m bits = 1 symbol
    l_ok = losses.rs_aware_loss(lg_ok, msg, t_symbols=code.t,
                                symbol_bits=code.m, k_symbols=code.k)
    # errors across 4 symbols: quadratic penalty
    lg_bad = _logits_with_errors(msg, 4 * code.m)
    l_bad = losses.rs_aware_loss(lg_bad, msg, t_symbols=code.t,
                                 symbol_bits=code.m, k_symbols=code.k)
    assert float(l_ok) < 0.05
    assert float(l_bad) > 4.0  # (4-1)^2 = 9 in expectation
    assert float(l_bad) > float(l_ok)


def test_qrmark_loss_parts():
    code = DEFAULT_CODE
    msg = jnp.asarray(np.random.default_rng(1).integers(
        0, 2, (2, code.codeword_bits)), jnp.float32)
    total, parts = losses.qrmark_loss(_logits_with_errors(msg, 0), msg,
                                      code=code)
    assert float(parts["L_RS"]) < 1e-3
    assert float(total) == pytest.approx(
        float(parts["L_m"]) + float(parts["L_RS"]), rel=1e-5)


# ---------------------------------------------------------------------------
# transforms / attacks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(transforms.ATTACKS))
def test_attacks_preserve_shape_and_finite(name):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1, 1, (2, 32, 32, 3)).astype(np.float32))
    y = transforms.ATTACKS[name](x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())


def test_jpeg_surrogate_removes_high_frequency():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1, 1, (1, 64, 64, 3)).astype(np.float32))
    y = transforms.attack_jpeg(x, quality=10)
    hf = lambda im: float(jnp.mean(jnp.square(
        im - transforms.attack_blur(im))))
    assert hf(y) < hf(x)


def test_preprocess_reference_pipeline():
    rng = np.random.default_rng(0)
    raw = jnp.asarray(rng.integers(0, 256, (2, 300, 300, 3),
                                   dtype=np.uint8))
    out = transforms.preprocess_reference(raw, resize=288, crop=256)
    assert out.shape == (2, 256, 256, 3)
    assert float(jnp.abs(out).max()) < 5.0


# ---------------------------------------------------------------------------
# LDM fine-tuning (§4.2) — tiny end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ldm_finetune_improves_extraction():
    from repro.core import ldm
    from repro.core.train_extractor import ExtractorTrainConfig, train

    tcfg = ExtractorTrainConfig(steps=50, batch=16, tile=16, img_size=64,
                                channels=16, depth=3, enc_channels=12,
                                enc_depth=2, curriculum_frac=1.0)
    hd = train(tcfg, log_every=1000, verbose=False)["params"]["dec"]
    ae = ldm.pretrain_autoencoder(jax.random.key(0), img_size=64,
                                  steps=60, batch=8)
    # container-scale fine-tune: stronger lr / lighter perceptual weight
    # than the paper's (1e-4, lam_i=2) so ~100 CPU iterations move the
    # needle (measured: 0.52 -> 0.73 over 120 steps); the library
    # defaults keep the paper's values
    res = ldm.finetune_decoder(ae, hd, tile=16, img_size=64, steps=120,
                               batch=4, lr=5e-3, lam_i=0.1)
    accs = [h["bit_acc"] for h in res.history]
    assert accs[-1] > accs[0] + 0.1, \
        f"fine-tune did not move extraction acc: {accs[0]} -> {accs[-1]}"


# ---------------------------------------------------------------------------
# tile-size predictor (App B.2)
# ---------------------------------------------------------------------------


def test_boosted_stumps_fit_simple_function():
    from repro.core.predictor import fit_boosted_stumps
    rng = np.random.default_rng(0)
    X = rng.uniform(-1, 1, (400, 3))
    y = np.where(X[:, 1] > 0.2, 32.0, 16.0)
    model = fit_boosted_stumps(X, y, n_rounds=60)
    pred = model.predict(X)
    acc = (np.abs(pred - y) < 8).mean()
    assert acc > 0.95


@pytest.mark.slow
def test_tile_size_predictor_separates_sizes():
    from repro.core.predictor import TileSizePredictor, train_predictor
    from repro.core.train_extractor import ExtractorTrainConfig, train

    pairs = {}
    for tile in (16, 32):
        cfg = ExtractorTrainConfig(steps=40, batch=12, tile=tile,
                                   img_size=tile * 4, channels=12, depth=2,
                                   enc_channels=10, enc_depth=2,
                                   curriculum_frac=1.0)
        params = train(cfg, log_every=1000, verbose=False)["params"]
        pairs[tile] = (params["enc"], cfg.code)
    pred = train_predictor(pairs, n_per_tile=24, img_size=64)
    from repro.core.predictor import build_training_set
    X, y = build_training_set(pairs, n_per_tile=12, img_size=64, seed=9)
    from repro.core.predictor import tile_features  # features precomputed
    raw = pred.model.predict(X)
    cands = np.asarray(pred.candidates, float)
    lab = cands[np.argmin(np.abs(raw[:, None] - cands[None, :]), axis=1)]
    acc = (lab == y).mean()
    assert acc > 0.7, f"predictor accuracy {acc}"
