"""Tests of the Reed-Solomon stack (paper Appendix A): numpy reference
codec, batched JAX decoder, GF tables, and the CPU pool.

Property-based tests run when ``hypothesis`` is installed; seeded-random
equivalents of each property always run, so the suite collects and
passes on a bare jax+numpy+pytest environment too.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.rs.codec import DEFAULT_CODE, RSCode, rs_decode, rs_encode
from repro.core.rs.gf import GF, bits_to_symbols, symbols_to_bits
from repro.core.rs import jax_rs
from repro.core.rs.cpu_pool import RSCodebook, RSCorrectionPool

CODES = [DEFAULT_CODE, RSCode(m=4, n=15, k=11), RSCode(m=8, n=32, k=24)]


# ---------------------------------------------------------------------------
# GF(2^m) field axioms — seeded-random versions (always run)
# ---------------------------------------------------------------------------


def _check_gf16_axioms(a, b, c):
    gf = GF(4)
    assert gf.mul(a, gf.mul(b, c)) == gf.mul(gf.mul(a, b), c)
    assert gf.mul(a, b) == gf.mul(b, a)
    assert gf.mul(a, gf.inv(a)) == 1
    # distributivity
    assert gf.mul(a, gf.add(b, c)) == gf.add(gf.mul(a, b), gf.mul(a, c))


def _check_gf256_mul_carryless(a, b):
    """Table multiply == carry-less polynomial multiply mod the primitive."""
    gf = GF(8)
    ref = 0
    x = a
    for i in range(8):
        if (b >> i) & 1:
            ref ^= x << i
    # reduce mod 0x11d
    for i in range(15, 7, -1):
        if (ref >> i) & 1:
            ref ^= 0x11d << (i - 8)
    assert int(gf.mul(a, b)) == ref


def test_gf16_field_axioms_seeded():
    rng = np.random.default_rng(0)
    for a, b, c in rng.integers(1, 16, (200, 3)):
        _check_gf16_axioms(int(a), int(b), int(c))


def test_gf256_mul_matches_carryless_seeded():
    rng = np.random.default_rng(1)
    for a, b in rng.integers(0, 256, (200, 2)):
        _check_gf256_mul_carryless(int(a), int(b))


def test_bits_symbols_roundtrip_seeded():
    rng = np.random.default_rng(2)
    for _ in range(20):
        bits = rng.integers(0, 2, 48).tolist()
        s = bits_to_symbols(bits, 4)
        assert np.array_equal(symbols_to_bits(s, 4), bits)


# ---------------------------------------------------------------------------
# codec properties — seeded-random versions (always run)
# ---------------------------------------------------------------------------


def _check_roundtrip_within_capacity(code, rng):
    msg = rng.integers(0, 2, code.message_bits)
    cw = rs_encode(code, msg)
    assert np.array_equal(cw[: code.message_bits], msg), "systematic"
    ne = int(rng.integers(0, code.t + 1))
    syms = rng.permutation(code.n)[:ne]
    bad = cw.copy()
    for s in syms:
        bad[s * code.m + int(rng.integers(0, code.m))] ^= 1
    res = rs_decode(code, bad)
    assert res.ok
    assert np.array_equal(res.message_bits, msg)
    assert res.n_corrected <= code.t


@pytest.mark.parametrize("code", CODES, ids=lambda c: f"n{c.n}k{c.k}m{c.m}")
def test_roundtrip_within_capacity_seeded(code):
    rng = np.random.default_rng(4)
    for _ in range(25):
        _check_roundtrip_within_capacity(code, rng)


def test_jax_decoder_matches_numpy_seeded():
    code = DEFAULT_CODE
    dec = jax_rs.make_batch_decoder(code)
    rng = np.random.default_rng(5)
    bits = rng.integers(0, 2, (20, code.codeword_bits))
    out = dec(bits)
    for i in range(bits.shape[0]):
        ref = rs_decode(code, bits[i])
        assert bool(out["ok"][i]) == ref.ok
        if ref.ok:
            assert np.array_equal(np.asarray(out["message_bits"][i]),
                                  ref.message_bits)


# ---------------------------------------------------------------------------
# property-based versions (hypothesis, when installed)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @given(st.integers(1, 15), st.integers(1, 15), st.integers(1, 15))
    def test_gf16_field_axioms(a, b, c):
        _check_gf16_axioms(a, b, c)

    @given(st.integers(0, 255), st.integers(0, 255))
    def test_gf256_mul_matches_carryless(a, b):
        _check_gf256_mul_carryless(a, b)

    @given(st.lists(st.integers(0, 1), min_size=48, max_size=48))
    def test_bits_symbols_roundtrip(bits):
        s = bits_to_symbols(bits, 4)
        assert np.array_equal(symbols_to_bits(s, 4), bits)

    @pytest.mark.parametrize("code", CODES,
                             ids=lambda c: f"n{c.n}k{c.k}m{c.m}")
    @settings(max_examples=25, deadline=None)
    @given(data=st.data())
    def test_roundtrip_within_capacity(code, data):
        msg = np.array(data.draw(st.lists(st.integers(0, 1),
                                          min_size=code.message_bits,
                                          max_size=code.message_bits)))
        cw = rs_encode(code, msg)
        assert np.array_equal(cw[: code.message_bits], msg), "systematic"
        ne = data.draw(st.integers(0, code.t))
        syms = data.draw(st.permutations(range(code.n)))[:ne]
        bad = cw.copy()
        for s in syms:
            bit = data.draw(st.integers(0, code.m - 1))
            bad[s * code.m + bit] ^= 1
        res = rs_decode(code, bad)
        assert res.ok
        assert np.array_equal(res.message_bits, msg)
        assert res.n_corrected <= code.t

    @settings(max_examples=20, deadline=None)
    @given(data=st.data())
    def test_jax_decoder_matches_numpy(data):
        code = DEFAULT_CODE
        dec = jax_rs.make_batch_decoder(code)
        bits = np.array(data.draw(st.lists(
            st.integers(0, 1), min_size=code.codeword_bits,
            max_size=code.codeword_bits)))[None, :]
        ref = rs_decode(code, bits[0])
        out = dec(bits)
        assert bool(out["ok"][0]) == ref.ok
        if ref.ok:
            assert np.array_equal(np.asarray(out["message_bits"][0]),
                                  ref.message_bits)


# ---------------------------------------------------------------------------
# deterministic batch / capacity tests (always run)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("code", CODES[:2], ids=lambda c: f"n{c.n}k{c.k}")
def test_jax_encoder_matches_numpy(code):
    rng = np.random.default_rng(0)
    enc = jax_rs.make_encoder(code)
    msgs = rng.integers(0, 2, (32, code.message_bits))
    ref = np.stack([rs_encode(code, m) for m in msgs])
    assert np.array_equal(np.asarray(enc(msgs)), ref)


def test_jax_batch_roundtrip_with_errors():
    code = DEFAULT_CODE
    rng = np.random.default_rng(3)
    dec = jax_rs.make_batch_decoder(code)
    B = 64
    msgs = rng.integers(0, 2, (B, code.message_bits))
    cws = np.stack([rs_encode(code, m) for m in msgs])
    bad = cws.copy()
    for i in range(B):
        s = rng.integers(0, code.n)
        bad[i, s * code.m + rng.integers(0, code.m)] ^= 1
    out = dec(bad)
    assert np.asarray(out["ok"]).all()
    assert np.array_equal(np.asarray(out["message_bits"]), msgs)


def test_beyond_capacity_fails_closed():
    code = DEFAULT_CODE
    rng = np.random.default_rng(1)
    for _ in range(20):
        msg = rng.integers(0, 2, code.message_bits)
        cw = rs_encode(code, msg)
        bad = cw.copy()
        for s in rng.choice(code.n, code.t + 2, replace=False):
            bad[s * code.m + rng.integers(0, code.m)] ^= 1
        res = rs_decode(code, bad)
        assert (not res.ok) or (not np.array_equal(res.message_bits, msg)) \
            or True  # decoding to a *different* valid word is permissible,
        # but silently claiming the original with too many errors is not:
        if res.ok:
            assert res.n_corrected <= code.t


# ---------------------------------------------------------------------------
# CPU pool + codebook (paper §5.3)
# ---------------------------------------------------------------------------


def test_cpu_pool_and_codebook():
    code = DEFAULT_CODE
    rng = np.random.default_rng(2)
    msg = rng.integers(0, 2, code.message_bits)
    cw = rs_encode(code, msg)
    pool = RSCorrectionPool(code, n_threads=4)
    try:
        batch = np.tile(cw, (16, 1))
        batch[3, 0] ^= 1  # one corrupted copy
        pool.submit_batch(batch)
        res = pool.drain(range(16))
        for m, ok in res:
            assert ok
            assert np.array_equal(m, msg)
        # the repeated word must hit the codebook
        assert pool.codebook.hits > 0
    finally:
        pool.close()


def test_codebook_eviction():
    cb = RSCodebook(capacity=4)
    rng = np.random.default_rng(0)
    words = rng.integers(0, 2, (8, 60))
    for w in words:
        cb.insert(w, w[:48], True)
    hits = sum(cb.lookup(w) is not None for w in words)
    assert hits <= 4
