"""Fused extractor decode kernel: fp32 bit-exactness vs the unfused
``extractor_forward``, semantic parity with the conv-formulation oracle,
the bf16 precision policy, packed-params round-trip, and end-to-end
engine equality through the detection pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.extractor import (extractor_forward, init_extractor,
                                  pack_params, unpack_params)
from repro.core.rs.codec import DEFAULT_CODE
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _tiles(b, l, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1, 1, (b, l, l, 3)).astype(np.float32))


def _params(l, *, corr=True, n_bits=60, channels=8, depth=2, seed=0):
    return init_extractor(jax.random.key(seed), n_bits=n_bits,
                          channels=channels, depth=depth,
                          tile=l if corr else 0)


# ---------------------------------------------------------------------------
# kernel-level contracts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("corr", [True, False])
@pytest.mark.parametrize("tile", [32, 64, 128])
def test_fused_fp32_bit_exact_vs_unfused(tile, corr):
    """The tentpole contract: the fp32 kernel is bit-identical to the
    unfused extractor_forward graph (they share the packed matmul body),
    with and without the correlation bank, at every tile size."""
    params = _params(tile, corr=corr)
    tiles = _tiles(2, tile, seed=tile)
    packed = pack_params(params)
    fused = np.asarray(jax.jit(
        lambda t: kops.fused_extractor(t, packed))(tiles))
    unfused = np.asarray(jax.jit(extractor_forward)(params, tiles))
    np.testing.assert_array_equal(fused, unfused)
    # and both match the original conv/einsum formulation semantically
    oracle = np.asarray(jax.jit(kref.fused_extractor_ref)(params, tiles))
    np.testing.assert_allclose(fused, oracle, atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("b", [1, 3, 5])
def test_fused_ragged_batches(b):
    """Batch-stability: every row of a size-b batch equals the same row
    of a larger batch (ragged serving slices must be inert)."""
    params = _params(32)
    packed = pack_params(params)
    f = jax.jit(lambda t: kops.fused_extractor(t, packed))
    full = np.asarray(f(_tiles(5, 32)))
    part = np.asarray(f(_tiles(5, 32)[:b]))
    np.testing.assert_array_equal(part, full[:b])


def test_fused_bf16_logit_tolerance():
    """bf16 packs compute the matmuls at bf16 with fp32 accumulation:
    logits stay within a small absolute tolerance of fp32 and almost
    every bit sign is preserved (RS absorbs the stragglers)."""
    params = _params(32, channels=16, depth=3)
    tiles = _tiles(4, 32, seed=3)
    f32 = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, pack_params(params, "fp32")))(tiles))
    b16 = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, pack_params(params, "bf16")))(tiles))
    assert b16.dtype == np.float32  # accumulation/output stay fp32
    np.testing.assert_allclose(b16, f32, atol=0.05)
    assert ((b16 > 0) == (f32 > 0)).mean() > 0.97


def test_pack_params_roundtrip():
    """pack_params -> unpack_params is exact for fp32 packs, and
    re-packing the unpacked params reproduces the pack bitwise."""
    params = _params(32, channels=16, depth=3)
    packed = pack_params(params)
    back = unpack_params(packed)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, back)
    repacked = pack_params(back)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), packed, repacked)
    # bf16 packs carry the compute dtype on every matmul operand
    p16 = pack_params(params, "bf16")
    for leaf in (p16["blocks"][0]["w"], p16["to_bits"]["w"],
                 p16["head"]["w"], p16["corr"]):
        assert leaf.dtype == jnp.bfloat16
    for leaf in (p16["blocks"][0]["b"], p16["head"]["b"],
                 p16["corr_scale"]):
        assert leaf.dtype == jnp.float32


# ---------------------------------------------------------------------------
# end-to-end engine equality through the detection pipeline
# ---------------------------------------------------------------------------


def _engine_outputs(cfg, params, raw, stream):
    from repro.core.detect import DetectionPipeline
    pipe = DetectionPipeline(cfg, params)
    try:
        out = {
            "batch": pipe.detect_batch(raw.copy(),
                                       key=jax.random.key(1)),
            "sharded": pipe.run_batch(raw, key=jax.random.key(1)),
            "lanes": {k: np.concatenate([r[k] for r in
                                         pipe.run_stream(stream,
                                                         lanes=2)
                                         ["results"]])
                      for k in ("message_bits", "ok", "logits")},
        }
    finally:
        pipe.close()
    return out


@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
def test_decode_engines_bit_identical(dtype):
    """Every engine — the fused single-jit fast path (detect_batch),
    the sharded run_batch, and the lane executor — produces identical
    message_bits/ok/logits for the same keys; and in fp32 the fused
    kernel pipelines equal the unfused ones bit for bit."""
    from repro.core.detect import DetectionConfig
    params = _params(16, n_bits=DEFAULT_CODE.codeword_bits,
                     channels=8, depth=2)
    rng = np.random.default_rng(7)
    raw = rng.integers(0, 256, (5, 64, 64, 3), dtype=np.uint8)
    stream = [rng.integers(0, 256, (4, 64, 64, 3), dtype=np.uint8)
              for _ in range(2)]

    def mk(**kw):
        base = dict(tile=16, img_size=32, resize_src=40, mode="qrmark",
                    rs_mode="device", code=DEFAULT_CODE,
                    decode_dtype=dtype)
        base.update(kw)
        return DetectionConfig(**base)

    fused = _engine_outputs(mk(), params, raw, stream)
    # detect_batch and run_batch share the key -> must agree exactly
    for f in ("message_bits", "ok", "logits"):
        np.testing.assert_array_equal(
            fused["batch"][f], fused["sharded"][f],
            err_msg=f"batch vs sharded {f} ({dtype})")
    assert fused["lanes"]["logits"].shape == (8, DEFAULT_CODE.codeword_bits)
    if dtype == "fp32":
        unfused = _engine_outputs(mk(fused_decode=False), params, raw,
                                  stream)
        for eng in ("batch", "sharded", "lanes"):
            for f in ("message_bits", "ok", "logits"):
                np.testing.assert_array_equal(
                    fused[eng][f], unfused[eng][f],
                    err_msg=f"fused vs unfused {eng}/{f}")
    else:
        # the lane executor must reproduce the fused fast path bitwise
        # under bf16 too: rerun the stream through a fresh pipeline at a
        # different lane count and compare
        again = _engine_outputs(mk(), params, raw, stream)
        for f in ("message_bits", "ok", "logits"):
            np.testing.assert_array_equal(
                fused["lanes"][f], again["lanes"][f],
                err_msg=f"lanes rerun {f} (bf16)")
