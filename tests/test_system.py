"""System-level integration tests: training loop convergence, the
detection service with adaptive allocation + LPT scheduling, the data
pipeline determinism contract, and interleaving."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec, all_configs, reduced
from repro.core.interleave import PrefetchIterator, interleaved
from repro.data import pipeline as data_lib


def test_train_loop_loss_decreases(tmp_path):
    from repro.launch.train import train_loop
    cfg = reduced(all_configs()["smollm-360m"])
    shape = ShapeSpec("t", 64, 4, "train")
    out = train_loop(cfg, shape, steps=30, ckpt_dir=None, log_every=1,
                     verbose=False)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.3, \
        f"loss did not decrease: {losses[0]} -> {losses[-1]}"


def test_detection_service_warmup_and_serve():
    from repro.core.detect import DetectionConfig
    from repro.core.extractor import init_extractor
    from repro.core.rs.codec import DEFAULT_CODE
    from repro.launch.serve import DetectionService

    params = init_extractor(jax.random.key(0),
                            n_bits=DEFAULT_CODE.codeword_bits,
                            channels=8, depth=2)
    cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                          mode="qrmark", rs_mode="device")
    svc = DetectionService(cfg, params, lane_budget=6)
    sample = np.stack([data_lib.synth_image(i, 48) for i in range(8)])
    alloc = svc.warmup(sample)
    assert sum(alloc.streams) <= 6
    assert all(s >= 1 for s in alloc.streams)
    batches = [np.stack([data_lib.synth_image(100 + k * 8 + i, 48)
                         for i in range(8)]) for k in range(2)]
    rep = svc.serve(batches)
    assert rep.images == 16
    assert rep.throughput_ips > 0


def test_data_pipeline_determinism():
    a = data_lib.synth_image(42, 64, seed=1)
    b = data_lib.synth_image(42, 64, seed=1)
    c = data_lib.synth_image(43, 64, seed=1)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    t1 = data_lib.token_batch(5, 2, 32, 100, seed=3)
    t2 = data_lib.token_batch(5, 2, 32, 100, seed=3)
    np.testing.assert_array_equal(t1, t2)


def test_worker_shards_are_disjoint():
    s0 = data_lib.ImageShard(worker=0, n_workers=2, batch=2, size=32)
    s1 = data_lib.ImageShard(worker=1, n_workers=2, batch=2, size=32)
    b0 = next(iter(s0.batches(1)))
    b1 = next(iter(s1.batches(1)))
    assert not np.array_equal(b0, b1)


def test_prefetch_iterator_preserves_order_and_errors():
    out = list(PrefetchIterator(range(10), prepare=lambda x: x * 2,
                                device_put=False))
    assert out == [i * 2 for i in range(10)]

    def bad(x):
        if x == 3:
            raise ValueError("boom")
        return x

    it = PrefetchIterator(range(5), prepare=bad, device_put=False)
    with pytest.raises(ValueError):
        list(it)


def test_lm_batches_match_input_specs():
    from repro.models import lm
    for arch in ("smollm-360m", "seamless-m4t-medium", "llava-next-34b"):
        cfg = all_configs()[arch]
        shape = ShapeSpec("t", 128 if arch != "llava-next-34b" else 2944,
                          2, "train")
        spec = lm.input_specs(cfg, shape)["batch"]
        batch = next(iter(data_lib.lm_batches(cfg, shape, n_steps=1)))
        for k, v in spec.items():
            assert k in batch, f"{arch}: missing {k}"
            assert tuple(batch[k].shape) == tuple(v.shape), \
                f"{arch}/{k}: {batch[k].shape} != {v.shape}"
