"""Fleet router tests: fault injection (replica crash mid-batch,
induced admission spill-over, rolling reconfigure under load, close
semantics), the fleet-vs-single-server bit-identity anchor, and the
rendezvous-routing stability property.

Every failure scenario is expressed as a :class:`FaultPlan` on the
replica wrapper — data handed to the replica's public seams — never by
monkeypatching server internals, so the tests exercise exactly the
injection points the wrapper contracts to honor.

Router-path deadlock canaries: the spill-over loop, drain-during-
reconfigure, and crash-during-drain scenarios all wear the ``deadline``
marker (tests/canary.py), so a wedged router fails fast with a thread
dump instead of hanging CI.
"""
import threading
import time

import jax
import numpy as np
import pytest

from canary import deadline
from repro.core.detect import DetectionConfig, DetectionPipeline
from repro.core.extractor import init_extractor
from repro.core.rs.codec import DEFAULT_CODE
from repro.serving import (AdmissionError, BatcherConfig, DetectionServer,
                           FaultPlan, FleetRouter, Replica, ReplicaCrashed)
from repro.serving.router import rendezvous, rendezvous_order

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

_FIELDS = ("message_bits", "ok", "n_corrected", "logits")


@pytest.fixture(scope="module")
def tiny_params():
    return init_extractor(jax.random.key(0),
                          n_bits=DEFAULT_CODE.codeword_bits,
                          channels=8, depth=2)


def _cfg(**kw):
    base = dict(tile=16, img_size=32, resize_src=40, mode="qrmark",
                rs_mode="device")
    base.update(kw)
    return DetectionConfig(**base)


def _replica(name, params, *, cfg=None, plan=None, max_wait_ms=2.0,
             max_batch=4, max_queue=256):
    return Replica(name, cfg or _cfg(), params,
                   batcher=BatcherConfig(max_batch=max_batch,
                                         max_wait_ms=max_wait_ms,
                                         max_queue=max_queue),
                   fault_plan=plan)


def _reqs(n, seed, max_group=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 256, (int(rng.integers(1, max_group + 1)),
                                  64, 64, 3), dtype=np.uint8)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# rendezvous routing: stability property
# ---------------------------------------------------------------------------


def _digests(rng, n):
    return [rng.bytes(32) for _ in range(n)]


def _check_rendezvous_stability(digests, names):
    """The property the fleet leans on: deterministic mapping, and
    add/remove of one replica remaps at most ~1/N of the keyspace."""
    base = {d: rendezvous(d, names) for d in digests}
    # determinism: same digests, same (shuffled) name list -> same owner
    shuffled = list(reversed(names))
    for d in digests:
        assert rendezvous(d, shuffled) == base[d]
    # removal: ONLY digests owned by the removed replica remap (exact
    # HRW property, not just a fraction bound)
    removed = names[0]
    survivors = [n for n in names if n != removed]
    for d in digests:
        if base[d] != removed:
            assert rendezvous(d, survivors) == base[d], \
                "removing one replica remapped a digest it did not own"
    # addition: the new replica steals ~1/(N+1); nothing else moves
    grown = names + ["new-replica"]
    moved = 0
    for d in digests:
        owner = rendezvous(d, grown)
        if owner != base[d]:
            assert owner == "new-replica", \
                "adding a replica remapped a digest to an OLD replica"
            moved += 1
    # expected |digests|/(N+1); assert a generous 3x bound so the test
    # checks the mechanism, not hash luck
    bound = max(4, 3 * len(digests) // (len(names) + 1))
    assert moved <= bound, f"adding one replica moved {moved} digests"


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           n_replicas=st.integers(2, 8),
           n_digests=st.integers(8, 64))
    def test_rendezvous_stability_property(seed, n_replicas, n_digests):
        rng = np.random.default_rng(seed)
        names = [f"r{i}" for i in range(n_replicas)]
        _check_rendezvous_stability(_digests(rng, n_digests), names)
else:                                                  # pragma: no cover
    def test_rendezvous_stability_property():
        for seed in range(10):
            rng = np.random.default_rng(seed)
            names = [f"r{i}" for i in range(2 + seed % 7)]
            _check_rendezvous_stability(
                _digests(rng, 8 + 8 * (seed % 5)), names)


def test_rendezvous_order_is_a_permutation():
    names = [f"r{i}" for i in range(5)]
    order = rendezvous_order(b"digest", names)
    assert sorted(order) == sorted(names)
    with pytest.raises(ValueError):
        rendezvous(b"digest", [])


# ---------------------------------------------------------------------------
# fleet == single server, bit for bit
# ---------------------------------------------------------------------------


@deadline(600)
def test_fleet_bit_identity_across_replica_counts(tiny_params):
    """The same request set — explicit-key AND content-key/cache_exact
    traffic (with repeats, so the cache tier actually fires) — routed
    through 1, 2, and 4 replicas is bitwise identical to a single
    DetectionServer: keys derive from content or the caller, never
    from placement."""
    cfg = _cfg(cache_exact=True)
    reqs = _reqs(6, seed=7)
    reqs.append(reqs[0].copy())          # exact repeat: cache/dedup path
    keys = [jax.random.key(100 + i) if i % 2 else None
            for i in range(len(reqs))]   # mixed explicit / content-key

    def run(server_like):
        handles = [server_like.submit(r, key=k)
                   for r, k in zip(reqs, keys)]
        return [h.result(300) for h in handles]

    ref_srv = DetectionServer(
        cfg, tiny_params,
        batcher=BatcherConfig(max_batch=4, max_wait_ms=2.0)).start()
    try:
        ref = run(ref_srv)
    finally:
        ref_srv.close()

    for n in (1, 2, 4):
        router = FleetRouter(
            [_replica(f"r{i}", tiny_params, cfg=cfg)
             for i in range(n)]).start()
        try:
            got = run(router)
        finally:
            router.close()
        for i, (a, b) in enumerate(zip(ref, got)):
            for f in _FIELDS:
                np.testing.assert_array_equal(
                    a[f], b[f],
                    err_msg=f"{n} replicas, request {i}, field {f}: "
                            f"fleet != single server")


@deadline(300)
def test_fleet_cache_exact_traffic_hits_one_replicas_cache(tiny_params):
    """Content-digest routing sends identical pixels to the same
    replica, so the second submission of the same image is an exact
    cache hit somewhere in the fleet (routing to a different replica
    would silently zero the hit rate)."""
    cfg = _cfg(cache_exact=True)
    router = FleetRouter(
        [_replica(f"r{i}", tiny_params, cfg=cfg) for i in range(3)]
    ).start()
    img = np.random.default_rng(3).integers(
        0, 256, (1, 64, 64, 3), dtype=np.uint8)
    try:
        a = router.submit(img).result(120)
        assert router.drain(60)
        b = router.submit(img).result(120)
        stats = router.stats()
    finally:
        router.close()
    assert stats["fleet_counters"].get("cache_hit_exact", 0) >= 1, \
        "repeat of identical pixels missed the fleet's exact cache"
    for f in _FIELDS:
        np.testing.assert_array_equal(a[f], b[f])


# ---------------------------------------------------------------------------
# fault injection: crash mid-batch, spill-over, rolling reconfigure, close
# ---------------------------------------------------------------------------


@deadline(300)
def test_crash_mid_batch_resolves_via_sibling(tiny_params):
    """A replica that dies with admitted-but-unresolved requests: every
    handle it held must resolve via re-execution on a sibling
    (first-completion-wins), bitwise equal to the offline engine."""
    # long max_wait on the doomed replica so its first admitted request
    # is still queued (mid-batch) when the crash lands
    reps = [_replica("doomed", tiny_params,
                     plan=FaultPlan(crash_after_admit=0),
                     max_wait_ms=100.0),
            _replica("healthy", tiny_params)]
    router = FleetRouter(reps).start()
    reqs = _reqs(8, seed=11)
    keys = [jax.random.key(i) for i in range(len(reqs))]
    try:
        handles, results = [], []
        for r, k in zip(reqs, keys):
            handles.append(router.submit(r, key=k))
        results = [h.result(120) for h in handles]
        stats = router.stats()
    finally:
        router.close()
    assert stats["reroutes"] >= 1, "no request was re-executed"
    assert stats["unhealthy"] == 1
    assert stats["counters"].get("requests_failed", 0) == 0
    assert any(h.reroutes for h in handles)
    rerouted = [h for h in handles if h.reroutes]
    assert all(h.replica == "healthy" for h in rerouted)
    pipe = DetectionPipeline(_cfg(), tiny_params)
    for r, k, res in zip(reqs, keys, results):
        ref = pipe.detect_batch(r, key=k)
        for f in _FIELDS:
            np.testing.assert_array_equal(ref[f], res[f])


@deadline(300)
def test_spillover_on_induced_admission_error(tiny_params):
    """Induced AdmissionError on the rendezvous owner: the router must
    place the request on the least-loaded healthy sibling, count the
    spill-over, and results must not change."""
    reps = [_replica("full", tiny_params,
                     plan=FaultPlan(reject_submits=1000)),
            _replica("sib-a", tiny_params),
            _replica("sib-b", tiny_params)]
    router = FleetRouter(reps).start()
    reqs = _reqs(9, seed=13)
    keys = [jax.random.key(40 + i) for i in range(len(reqs))]
    try:
        handles = [router.submit(r, key=k) for r, k in zip(reqs, keys)]
        results = [h.result(120) for h in handles]
        stats = router.stats()
    finally:
        router.close()
    assert stats["spillovers"] >= 1, "owner rejected but nothing spilled"
    assert stats["counters"].get("requests_failed", 0) == 0
    spilled = [h for h in handles if h.spilled]
    assert spilled and all(h.replica != "full" for h in spilled)
    pipe = DetectionPipeline(_cfg(), tiny_params)
    for r, k, res in zip(reqs, keys, results):
        ref = pipe.detect_batch(r, key=k)
        for f in _FIELDS:
            np.testing.assert_array_equal(ref[f], res[f])


@deadline(600)
def test_rolling_reconfigure_under_load_zero_drops(tiny_params):
    """Drain-one / reconfigure / return-to-rotation across the fleet
    while a submitter thread keeps offering traffic: every admitted
    request resolves (zero dropped, zero unresolved), and the new lane
    map is applied to every healthy replica."""
    router = FleetRouter(
        [_replica(f"r{i}", tiny_params) for i in range(3)])
    rng = np.random.default_rng(17)
    # compile before offering load: the roll must be measured against
    # steady-state replicas, not first-request jit stalls that back the
    # queues up to their admission bound
    router.warmup(rng.integers(0, 256, (64, 64, 3), dtype=np.uint8))
    router.start()
    handles, submit_err = [], []
    stop = threading.Event()

    def pump():
        k = 0
        while not stop.is_set():
            img = rng.integers(0, 256, (1, 64, 64, 3), dtype=np.uint8)
            try:
                handles.append(router.submit(img,
                                             key=jax.random.key(k)))
            except AdmissionError as e:   # zero-drop means NO rejects
                submit_err.append(e)
            k += 1
            time.sleep(0.02)

    t = threading.Thread(target=pump, daemon=True)
    t.start()
    try:
        time.sleep(0.15)                 # traffic flowing
        applied = router.rolling_reconfigure(
            {"ingest": 1, "decode": 2, "rs": 1}, drain_timeout=60.0)
        time.sleep(0.15)                 # traffic after the roll
    finally:
        stop.set()
        t.join(10.0)
    try:
        assert len(applied) == 3
        assert all(v == {"ingest": 1, "decode": 2, "rs": 1}
                   for v in applied.values())
        assert not submit_err, f"requests dropped during the roll: " \
                               f"{submit_err[0]}"
        results = [h.result(120) for h in handles]
        assert len(results) == len(handles)
        stats = router.stats()
        assert stats["counters"].get("requests_failed", 0) == 0
        assert stats["unhealthy"] == 0
    finally:
        router.close()


@deadline(300)
def test_router_close_rejects_pending_exactly_once(tiny_params):
    """Non-graceful close with requests still queued: every pending
    handle is rejected — and its done-callback fires exactly once (no
    double settlement through the replica-kill and router-sweep
    paths)."""
    # huge max_wait + max_batch: submissions sit in the batcher queue,
    # guaranteed pending at close
    reps = [_replica(f"r{i}", tiny_params, max_wait_ms=60_000.0,
                     max_batch=32) for i in range(2)]
    router = FleetRouter(reps).start()
    reqs = _reqs(6, seed=23, max_group=2)
    handles = [router.submit(r, key=jax.random.key(i))
               for i, r in enumerate(reqs)]
    fired = {h.rid: 0 for h in handles}
    for h in handles:
        h.add_done_callback(lambda hh: fired.__setitem__(
            hh.rid, fired[hh.rid] + 1))
    router.close(graceful=False)
    for h in handles:
        assert h.done(), "close() left a fleet handle unresolved"
        with pytest.raises(RuntimeError):
            h.result(0)
    assert all(v == 1 for v in fired.values()), \
        f"settlement not exactly-once: {fired}"
    # a closed router refuses new work loudly
    with pytest.raises(AdmissionError, match="closed"):
        router.submit(reqs[0])
    # idempotent close
    router.close()


# ---------------------------------------------------------------------------
# router-path deadlock canaries
# ---------------------------------------------------------------------------


@deadline(120)
def test_spillover_loop_terminates_when_all_reject(tiny_params):
    """Whole-fleet backpressure: every replica induces AdmissionError.
    The spill-over pass must visit each candidate once and surface
    AdmissionError to the caller — not loop forever."""
    reps = [_replica(f"r{i}", tiny_params,
                     plan=FaultPlan(reject_submits=1000))
            for i in range(3)]
    router = FleetRouter(reps).start()
    img = np.zeros((1, 64, 64, 3), np.uint8)
    try:
        with pytest.raises(AdmissionError, match="no healthy replica"):
            router.submit(img, key=jax.random.key(0))
        assert router.stats()["counters"].get("requests_rejected") == 1
        # fleet drains trivially — nothing was admitted
        assert router.drain(5)
    finally:
        router.close()


@deadline(300)
def test_drain_during_reconfigure_no_deadlock(tiny_params):
    """drain() concurrent with rolling_reconfigure(): both must
    complete — the roll's out-of-rotation window must not strand a
    request where drain can never see it settle."""
    router = FleetRouter(
        [_replica(f"r{i}", tiny_params) for i in range(2)]).start()
    reqs = _reqs(6, seed=29, max_group=2)
    done = {}
    try:
        handles = [router.submit(r, key=jax.random.key(i))
                   for i, r in enumerate(reqs)]

        def roll():
            done["applied"] = router.rolling_reconfigure(
                drain_timeout=60.0)

        t = threading.Thread(target=roll, daemon=True)
        t.start()
        assert router.drain(timeout=120.0), "drain wedged during roll"
        t.join(120.0)
        assert not t.is_alive(), "rolling_reconfigure wedged"
        assert len(done["applied"]) == 2
        [h.result(60) for h in handles]
    finally:
        router.close()


@deadline(300)
def test_crash_during_drain_does_not_wedge_roll(tiny_params):
    """A replica that crashes while being drained for reconfigure: the
    roll marks it unhealthy and moves on; its in-flight work re-executes
    on siblings; subsequent traffic still completes."""
    reps = [_replica("fragile", tiny_params,
                     plan=FaultPlan(crash_on_drain=True),
                     max_wait_ms=100.0),
            _replica("steady", tiny_params)]
    router = FleetRouter(reps).start()
    reqs = _reqs(6, seed=31, max_group=2)
    keys = [jax.random.key(60 + i) for i in range(len(reqs))]
    try:
        handles = [router.submit(r, key=k) for r, k in zip(reqs, keys)]
        applied = router.rolling_reconfigure(drain_timeout=60.0)
        # the fragile replica died mid-roll: only the survivor applied
        assert list(applied) == ["steady"]
        stats = router.stats()
        assert stats["unhealthy"] == 1
        assert not router._replicas["fragile"].healthy
        # every pre-roll request still resolves (sibling re-execution
        # for anything the crash took down)
        results = [h.result(120) for h in handles]
        # traffic after the roll lands on the survivor
        post = router.submit(reqs[0], key=keys[0])
        assert post.result(120) is not None
        assert post.replica == "steady"
    finally:
        router.close()
    pipe = DetectionPipeline(_cfg(), tiny_params)
    for r, k, res in zip(reqs, keys, results):
        ref = pipe.detect_batch(r, key=k)
        for f in _FIELDS:
            np.testing.assert_array_equal(ref[f], res[f])


# ---------------------------------------------------------------------------
# replica wrapper seams
# ---------------------------------------------------------------------------


@deadline(120)
def test_replica_fault_plan_seams(tiny_params):
    """The FaultPlan injection points are the wrapper's public
    contract: induced rejections decrement, crash flips healthy exactly
    once, and a dead replica refuses work with ReplicaCrashed."""
    rep = _replica("r0", tiny_params,
                   plan=FaultPlan(reject_submits=2)).start()
    img = np.zeros((1, 64, 64, 3), np.uint8)
    try:
        for _ in range(2):
            with pytest.raises(AdmissionError, match="induced"):
                rep.submit(img, key=jax.random.key(0))
        h = rep.submit(img, key=jax.random.key(0))
        assert h.result(60) is not None
        assert rep.healthy
        rep.crash("test")
        assert not rep.healthy
        rep.crash("second crash is a no-op")
        with pytest.raises(ReplicaCrashed):
            rep.submit(img, key=jax.random.key(0))
        load = rep.load()
        assert load["headroom"] == 0 and load["queue_depth"] >= 1 << 30
        assert rep.drain(0.1) is False
        with pytest.raises(ReplicaCrashed):
            rep.reconfigure({"ingest": 1, "decode": 1, "rs": 1})
    finally:
        rep.close()     # no-op after crash, must not raise


@deadline(120)
def test_server_kill_rejects_inflight_and_queued(tiny_params):
    """DetectionServer.kill (the crash primitive): no drain, every
    admitted handle settles with the supplied error."""
    srv = DetectionServer(
        _cfg(), tiny_params,
        batcher=BatcherConfig(max_batch=32,
                              max_wait_ms=60_000.0)).start()
    rng = np.random.default_rng(37)
    handles = [srv.submit(rng.integers(0, 256, (1, 64, 64, 3),
                                       dtype=np.uint8),
                          key=jax.random.key(i)) for i in range(4)]
    srv.kill(ReplicaCrashed("test kill"))
    for h in handles:
        assert h.done(), "kill() left a handle unresolved"
        with pytest.raises(RuntimeError):
            h.result(0)
