"""Per-architecture smoke tests (reduced configs): one forward/train step
on CPU asserting output shapes + no NaNs, plus decode==prefill
consistency for every cache type."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (SHAPES_BY_NAME, all_configs, cell_enabled,
                                reduced)
from repro.models import lm
from repro.train import optimizer as opt_lib, step as step_lib

ARCHS = sorted(all_configs().keys())


def tiny_batch(cfg, b=2, s=32, seed=0):
    key = jax.random.key(seed)
    if cfg.is_encoder_decoder:
        return {"frame_embeds": jax.random.normal(
            key, (b, s, cfg.d_model), jnp.bfloat16),
            "tgt_tokens": jax.random.randint(key, (b, max(8, s // 4)), 0,
                                             cfg.vocab)}
    if cfg.frontend == "vision":
        nf = cfg.n_frontend_tokens
        return {"tokens": jax.random.randint(key, (b, s - nf), 0, cfg.vocab),
                "patch_embeds": jax.random.normal(
                    key, (b, nf, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduced(all_configs()[arch])
    params = lm.init_params(cfg, jax.random.key(0))
    batch = tiny_batch(cfg)
    loss = jax.jit(lambda p, b: lm.forward_train(p, b, cfg, remat=False))(
        params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    # one full optimizer step
    opt_cfg = opt_lib.AdamWConfig(total_steps=10)
    st = step_lib.make_train_step(cfg, opt_cfg, n_micro=1)
    opt_state = opt_lib.init_opt_state(params)
    p2, o2, metrics = jax.jit(st)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params must actually change
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_param_count_matches_config(arch):
    cfg = reduced(all_configs()[arch])
    params = lm.init_params(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    assert n == cfg.param_counts()["total"], \
        f"{arch}: params {n} != analytic {cfg.param_counts()['total']}"


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-2.7b",
                                  "jamba-1.5-large-398b", "h2o-danube-3-4b",
                                  "seamless-m4t-medium"])
def test_decode_matches_prefill(arch):
    cfg = reduced(all_configs()[arch])
    params = lm.init_params(cfg, jax.random.key(1))
    b, s = 2, 33
    key = jax.random.key(2)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.is_encoder_decoder:
        fe = jax.random.normal(key, (b, 24, cfg.d_model), jnp.bfloat16)
        full = {"frame_embeds": fe, "tgt_tokens": toks}
        pre = {"frame_embeds": fe, "tgt_tokens": toks[:, :-1]}
    else:
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :-1]}
    la, _ = jax.jit(lambda p, bt: lm.forward_prefill(p, bt, cfg))(params,
                                                                  full)
    _, state = jax.jit(lambda p, bt: lm.forward_prefill(p, bt, cfg))(params,
                                                                     pre)
    lb, _ = jax.jit(lambda p, t, st: lm.forward_decode(p, t, st, cfg))(
        params, toks[:, -1:], state)
    err = float(jnp.max(jnp.abs(la.astype(jnp.float32)
                                - lb.astype(jnp.float32))))
    assert err < 0.15, f"{arch}: decode/prefill mismatch {err}"


def test_long_context_skip_rules():
    cfgs = all_configs()
    long = SHAPES_BY_NAME["long_500k"]
    runs = {a for a, c in cfgs.items() if cell_enabled(c, long)[0]}
    assert runs == {"mamba2-2.7b", "jamba-1.5-large-398b",
                    "h2o-danube-3-4b"}


def test_unroll_matches_scan():
    cfg = reduced(all_configs()["smollm-360m"])
    params = lm.init_params(cfg, jax.random.key(0))
    batch = tiny_batch(cfg)
    l1 = lm.forward_train(params, batch, cfg, remat=False, unroll=False)
    l2 = lm.forward_train(params, batch, cfg, remat=False, unroll=True)
    assert abs(float(l1) - float(l2)) < 1e-3


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor 1.25 and balanced-ish routing, outputs must be
    finite and nonzero for most tokens."""
    from repro.models import moe as moe_lib
    from repro.configs.base import MoEConfig
    key = jax.random.key(0)
    moe = MoEConfig(n_experts=4, top_k=2)

    class C:
        d_model, d_ff = 16, 32
    params = moe_lib.init_moe(key, C, moe)
    x = jax.random.normal(key, (64, 16))
    out = moe_lib.moe_mlp(params, x, moe)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    nz = float((jnp.abs(out).sum(-1) > 0).mean())
    assert nz > 0.7
