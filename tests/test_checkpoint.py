"""Checkpoint/restore: atomicity, async save, retention, torn-checkpoint
rejection, and elastic restore; plus a crash-restart integration test of
the train loop (subprocess hard-kill at a step boundary)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck

SRC = str(Path(__file__).resolve().parents[1] / "src")


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": ({"w": jnp.ones((5,), jnp.bfloat16)},
                  {"w": jnp.zeros((2, 2), jnp.int32)})}


def assert_tree_equal(x, y):
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(tmp_path, 7, t)
    assert ck.latest_step(tmp_path) == 7
    out = ck.restore(tmp_path, 7, jax.eval_shape(lambda: t))
    assert_tree_equal(t, out)


def test_torn_checkpoint_is_ignored(tmp_path):
    t = tree()
    ck.save(tmp_path, 1, t)
    ck.save(tmp_path, 2, t)
    # simulate a crash mid-save: remove COMMIT from step 2
    (tmp_path / "step_00000002" / "COMMIT").unlink()
    assert ck.latest_step(tmp_path) == 1
    with pytest.raises(FileNotFoundError):
        ck.restore(tmp_path, 2, jax.eval_shape(lambda: t))


def test_retention_gc(tmp_path):
    t = tree()
    for s in range(6):
        ck.save(tmp_path, s, t, keep=2)
    assert ck.valid_steps(tmp_path) == [4, 5]


def test_async_checkpointer(tmp_path):
    t = tree()
    acp = ck.AsyncCheckpointer(tmp_path, keep=2)
    acp.save(1, t)
    acp.save(2, t)  # waits for 1
    acp.wait()
    assert ck.latest_step(tmp_path) == 2


def test_restore_shape_mismatch_raises(tmp_path):
    ck.save(tmp_path, 1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        ck.restore(tmp_path, 1, {"a": jax.ShapeDtypeStruct((4,),
                                                           jnp.float32)})


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Restore lays out against the CURRENT mesh (elastic rescale)."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(tmp_path, 3, t)
    from repro.launch.mesh import _mesh
    mesh = _mesh((1, 1), ("data", "model"))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    out = ck.restore(tmp_path, 3, jax.eval_shape(lambda: t), shardings=sh)
    assert out["w"].sharding.is_equivalent_to(sh["w"], 2)
    assert_tree_equal(t, out)


@pytest.mark.slow
def test_crash_restart_resumes_training(tmp_path):
    """Hard-kill the trainer at step 6, restart, verify it resumes from
    the checkpoint (not from scratch) and completes."""
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "smollm-360m", "--reduced", "--steps", "10",
            "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "5"]
    p1 = subprocess.run(args + ["--simulate-failure", "6"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p1.returncode == 42, p1.stderr[-2000:]
    assert ck.latest_step(tmp_path) == 5  # step-5 checkpoint survived
    p2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=600)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 5" in p2.stdout
