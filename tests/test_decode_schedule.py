"""Blocked decode schedule + precision ladder + autotune cache.

Contracts added by the schedule/precision PR:

* the blocked kernel (any batch_block x channel_tile point) is fp32
  bit-identical to the flat kernel and the unfused graph — the
  schedule is a pure throughput knob;
* the int8 rung: pack-time per-channel weight scales round-trip, the
  decode path is batch-stable, and on a margin-bearing (watermarked)
  workload int8 reaches decision agreement 1.0 with fp32;
* the autotune cache: deterministic winner re-load (a hit skips the
  sweep), corrupt/stale caches fall back to flat loudly, and keys
  separate backend/dtype/tile;
* config plumbing: ``decode_schedule`` reaches every engine without
  perturbing fp32 results.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.extractor import (extractor_forward, init_encoder,
                                  init_extractor, pack_params,
                                  quantize_weight_int8, unpack_params,
                                  encoder_forward)
from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.kernels import autotune as autotune_lib
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.autotune import Schedule
from repro.kernels.fused_extractor import fused_extractor_blocked


def _tiles(b, l, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1, 1, (b, l, l, 3)).astype(np.float32))


def _params(l, *, corr=True, n_bits=60, channels=8, depth=2, seed=0):
    return init_extractor(jax.random.key(seed), n_bits=n_bits,
                          channels=channels, depth=depth,
                          tile=l if corr else 0)


def _margined_workload(tile=32, batch=6, channels=8, depth=2):
    """Watermarked tiles whose logits carry a real margin (encoder and
    extractor share the spread-spectrum bank) — the deployment regime
    the precision ladder is judged in (mirrors fig10's workload)."""
    code = DEFAULT_CODE
    enc = init_encoder(jax.random.key(1), n_bits=code.codeword_bits,
                       channels=4, depth=2, tile=tile)
    params = init_extractor(jax.random.key(2), n_bits=code.codeword_bits,
                            channels=channels, depth=depth, tile=tile,
                            patterns=enc["patterns"])
    params["corr_scale"] = params["corr_scale"] * 4.0
    rng = np.random.default_rng(0)
    msg = rng.integers(0, 2, code.message_bits)
    cw = jnp.asarray(rs_encode(code, msg))
    imgs = jnp.asarray(rng.uniform(-1, 1, (batch, tile, tile, 3))
                       .astype(np.float32))
    tiles, _ = encoder_forward(
        enc, imgs, jnp.broadcast_to(cw, (batch, code.codeword_bits)))
    return params, tiles, code


# ---------------------------------------------------------------------------
# blocked-schedule fp32 bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile", [32, 64, 128])
def test_blocked_fp32_bit_identical_to_flat(tile):
    """Every blocked schedule point reproduces the flat grid=(b,) kernel
    (and hence the unfused graph) bit for bit at fp32."""
    params = _params(tile)
    packed = pack_params(params)
    tiles = _tiles(4, tile, seed=tile)
    flat = np.asarray(jax.jit(
        lambda t: kops.fused_extractor(t, packed))(tiles))
    np.testing.assert_array_equal(
        flat, np.asarray(jax.jit(extractor_forward)(params, tiles)))
    for bb, ct in ((1, 0), (2, 0), (4, 0), (1, 4), (2, 3)):
        blocked = np.asarray(jax.jit(
            lambda t, _bb=bb, _ct=ct: fused_extractor_blocked(
                t, packed, batch_block=_bb, channel_tile=_ct))(tiles))
        np.testing.assert_array_equal(
            blocked, flat, err_msg=f"bb={bb} ct={ct} tile={tile}")


@pytest.mark.parametrize("b", [1, 3, 5, 7])
def test_blocked_ragged_batches(b):
    """Ragged batches (b % batch_block != 0) are zero-padded and sliced;
    pad rows are inert so every row matches the flat kernel bitwise."""
    params = _params(32)
    packed = pack_params(params)
    full = np.asarray(jax.jit(
        lambda t: kops.fused_extractor(t, packed))(_tiles(7, 32)))
    sched = Schedule(batch_block=4, channel_tile=0)
    part = np.asarray(jax.jit(
        lambda t: kops.fused_extractor(t, packed, schedule=sched))(
            _tiles(7, 32)[:b]))
    np.testing.assert_array_equal(part, full[:b])


def test_ops_schedule_dispatch():
    """kops.fused_extractor(schedule=None) runs the flat kernel;
    a Schedule runs the blocked kernel — fp32 outputs identical."""
    params = _params(32)
    packed = pack_params(params)
    tiles = _tiles(3, 32)
    a = np.asarray(jax.jit(
        lambda t: kops.fused_extractor(t, packed))(tiles))
    c = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, packed, schedule=Schedule(2, 0, True)))(tiles))
    np.testing.assert_array_equal(a, c)


# ---------------------------------------------------------------------------
# int8 precision rung
# ---------------------------------------------------------------------------


def test_int8_weight_scale_roundtrip():
    """Symmetric per-channel quantization: dequantized weights are
    within half a quantization step of the originals, per channel."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(72, 16)).astype(np.float32) * 0.3)
    q, scale = quantize_weight_int8(w)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    assert scale.shape == (16,)
    deq = np.asarray(q, np.float32) * np.asarray(scale)[None, :]
    np.testing.assert_allclose(deq, np.asarray(w),
                               atol=float(np.asarray(scale).max()) / 2
                               + 1e-7)


def test_int8_pack_structure_and_unpack():
    """int8 packs: conv/to_bits weights int8 + fp32 scales, head and
    corr stay fp32; unpack_params dequantizes to q * scale exactly."""
    params = _params(32, channels=16, depth=3)
    pk = pack_params(params, "int8")
    for entry in (*pk["blocks"], pk["to_bits"]):
        assert entry["w"].dtype == jnp.int8
        assert entry["scale"].dtype == jnp.float32
        assert entry["b"].dtype == jnp.float32
    assert pk["head"]["w"].dtype == jnp.float32
    assert pk["corr"].dtype == jnp.float32
    back = unpack_params(pk)
    w0 = np.asarray(pk["blocks"][0]["w"], np.float32) * \
        np.asarray(pk["blocks"][0]["scale"])[None, :]
    np.testing.assert_array_equal(
        np.asarray(back["blocks"][0]["w"]).reshape(-1, 16), w0)


def test_int8_batch_stable_and_schedules_agree():
    """The int8 path quantizes activations per ROW, so it stays
    batch-stable, and flat vs blocked schedules agree bitwise at full
    channel width (same quantization, same accumulation order).
    Channel-tiled int8 is float-level only — the dequant multiply-add
    chain may fuse differently per tile width — so ct > 0 asserts ulp
    closeness and identical hard bits instead."""
    params = _params(32, channels=16, depth=3)
    pk = pack_params(params, "int8")
    tiles = _tiles(5, 32, seed=4)
    flat = jax.jit(lambda t: kops.fused_extractor(t, pk))
    full = np.asarray(flat(tiles))
    np.testing.assert_array_equal(np.asarray(flat(tiles[:2])), full[:2])
    blocked = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, pk, schedule=Schedule(2, 0, True)))(tiles))
    np.testing.assert_array_equal(blocked, full)
    ct = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, pk, schedule=Schedule(1, 4, True)))(tiles))
    np.testing.assert_allclose(ct, full, atol=1e-5)
    np.testing.assert_array_equal(ct > 0, full > 0)


def test_int8_matches_dequant_oracle():
    """int8 decode tracks the dequantized-weight fp32 oracle within the
    activation-quantization noise floor."""
    params = _params(32, channels=16, depth=3)
    pk = pack_params(params, "int8")
    tiles = _tiles(4, 32, seed=5)
    got = np.asarray(jax.jit(
        lambda t: kops.fused_extractor(t, pk))(tiles))
    want = np.asarray(jax.jit(
        lambda t: kref.fused_extractor_int8_ref(pk, t))(tiles))
    np.testing.assert_allclose(got, want, atol=0.15, rtol=0.05)


def test_int8_decision_agreement_on_margined_workload():
    """The acceptance contract for the bottom rung: on watermarked
    (margin-bearing) tiles, int8 and fp32 produce identical RS
    decisions (decision agreement 1.0) and near-identical hard bits."""
    params, tiles, code = _margined_workload()
    l32 = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, pack_params(params, "fp32")))(tiles))
    l8 = np.asarray(jax.jit(lambda t: kops.fused_extractor(
        t, pack_params(params, "int8")))(tiles))
    bit_acc = float(((l8 > 0) == (l32 > 0)).mean())
    assert bit_acc > 0.98
    dev_rs = jax.jit(lambda b: kops.rs_decode(b, code=code))
    r32 = dev_rs((jnp.asarray(l32) > 0).astype(jnp.int32))
    r8 = dev_rs((jnp.asarray(l8) > 0).astype(jnp.int32))
    assert np.array_equal(np.asarray(r32["message_bits"]),
                          np.asarray(r8["message_bits"]))
    assert np.array_equal(np.asarray(r32["ok"]), np.asarray(r8["ok"]))


# ---------------------------------------------------------------------------
# autotune: Schedule strings + cache behavior
# ---------------------------------------------------------------------------


def test_schedule_string_roundtrip():
    for sc in (Schedule(1, 0, True), Schedule(2, 32, False),
               Schedule(8, 16, True)):
        assert Schedule.from_string(sc.to_string()) == sc
    assert Schedule.from_string("bb2-ct32-db") == Schedule(2, 32, True)
    assert Schedule.from_string("bb4-ct0") == Schedule(4, 0, False)
    for bad in ("", "flat", "auto", "bb2", "ctx-bb1", "bb0-ct0",
                "bbx-ct1", "bb1-ct2-xx", "bb1-ct-1"):
        with pytest.raises(ValueError):
            Schedule.from_string(bad)


def test_schedule_keys_distinguish_axes():
    base = dict(backend="cpu", dtype="fp32", tile=64, channels=64,
                depth=7, n_bits=60)
    k0 = autotune_lib.schedule_key(**base)
    for axis, val in (("backend", "tpu"), ("dtype", "int8"),
                      ("tile", 32), ("channels", 32), ("depth", 3),
                      ("n_bits", 75)):
        assert autotune_lib.schedule_key(**{**base, axis: val}) != k0


def test_autotune_cache_hit_skips_sweep(tmp_path, monkeypatch):
    """First call sweeps and persists; the second reloads the winner
    deterministically WITHOUT sweeping (sweep stubbed to explode)."""
    params = _params(16, channels=4, depth=2)
    pk = pack_params(params)
    cache = tmp_path / "sched.json"
    logs = []
    sc1 = autotune_lib.autotune(pk, tile=16, batch=2, dtype="fp32",
                                cache_path=cache, iters=1, quick=True,
                                log=logs.append)
    assert cache.exists()

    def boom(*a, **k):
        raise AssertionError("sweep must not run on a cache hit")

    monkeypatch.setattr(autotune_lib, "sweep", boom)
    logs2 = []
    sc2 = autotune_lib.autotune(pk, tile=16, batch=2, dtype="fp32",
                                cache_path=cache, iters=1, quick=True,
                                log=logs2.append)
    assert sc2 == sc1
    assert any("cache hit" in m for m in logs2)


def test_flat_can_win_the_sweep(tmp_path, monkeypatch):
    """Flat is a sweep candidate: when every blocked point times slower,
    the cached winner is "flat" and autotune returns None (the flat
    kernel) — the tuner never crowns a losing schedule."""
    params = _params(16, channels=4, depth=2)
    pk = pack_params(params)
    walls = iter([0.001] + [0.002] * 16)  # flat first, then candidates

    def fake_time(fn, *a, **k):
        return next(walls)

    monkeypatch.setattr(autotune_lib, "time_fn", fake_time)
    cache = tmp_path / "sched.json"
    sc = autotune_lib.autotune(pk, tile=16, batch=2, dtype="fp32",
                               cache_path=cache, quick=True,
                               log=lambda *a, **k: None)
    assert sc is None
    entry = json.loads(cache.read_text())["entries"]
    (rec,) = entry.values()
    assert rec["schedule"] == "flat"
    assert rec["speedup_vs_flat"] == 1.0
    # and the cached flat winner round-trips as a hit, not a miss
    logs = []
    sc2 = autotune_lib.autotune(pk, tile=16, batch=2, dtype="fp32",
                                cache_path=cache, quick=True,
                                log=logs.append)
    assert sc2 is None
    assert any("cache hit" in m for m in logs)


def test_corrupt_cache_falls_back_loudly(tmp_path, capsys):
    cache = tmp_path / "sched.json"
    cache.write_text("{not json")
    loaded = autotune_lib.load_cache(cache)
    assert loaded["entries"] == {}
    assert "corrupt" in capsys.readouterr().err


def test_stale_cache_version_falls_back_loudly(tmp_path, capsys):
    cache = tmp_path / "sched.json"
    cache.write_text(json.dumps(
        {"version": -1, "entries": {"k": {"schedule": "bb2-ct0-db"}}}))
    loaded = autotune_lib.load_cache(cache)
    assert loaded["entries"] == {}
    assert "stale" in capsys.readouterr().err


def test_invalid_cached_schedule_falls_back_loudly(tmp_path, capsys):
    cache = tmp_path / "sched.json"
    key = autotune_lib.schedule_key(
        backend=jax.default_backend(), dtype="fp32", tile=16,
        channels=4, depth=2, n_bits=60)
    cache.write_text(json.dumps(
        {"version": autotune_lib.CACHE_VERSION,
         "entries": {key: {"schedule": "garbage"}}}))
    sc = autotune_lib.resolve_schedule(
        "auto", dtype="fp32", tile=16, channels=4, depth=2, n_bits=60,
        cache_path=cache)
    assert sc is None
    assert "invalid" in capsys.readouterr().err


def test_resolve_schedule_modes(tmp_path, capsys):
    kw = dict(dtype="fp32", tile=16, channels=4, depth=2, n_bits=60)
    assert autotune_lib.resolve_schedule("flat", **kw) is None
    assert autotune_lib.resolve_schedule("", **kw) is None
    assert autotune_lib.resolve_schedule(
        "bb2-ct8-db", **kw) == Schedule(2, 8, True)
    # auto with no cache configured / an empty cache: loud flat fallback
    assert autotune_lib.resolve_schedule("auto", **kw) is None
    assert "auto" in capsys.readouterr().err
    empty = tmp_path / "none.json"
    assert autotune_lib.resolve_schedule(
        "auto", **kw, cache_path=empty) is None
    assert "no cached schedule" in capsys.readouterr().err
    with pytest.raises(ValueError):
        autotune_lib.resolve_schedule("bogus", **kw)


# ---------------------------------------------------------------------------
# engine plumbing
# ---------------------------------------------------------------------------


def test_engines_identical_under_tuned_schedule():
    """decode_schedule reaches detect_batch / run_batch / the lane
    executor without perturbing fp32 results: a tuned-schedule pipeline
    equals the flat-schedule one bitwise on every engine output."""
    from repro.core.detect import DetectionConfig, DetectionPipeline
    params = _params(16, n_bits=DEFAULT_CODE.codeword_bits,
                     channels=8, depth=2)
    rng = np.random.default_rng(7)
    raw = rng.integers(0, 256, (5, 64, 64, 3), dtype=np.uint8)

    def run(schedule):
        cfg = DetectionConfig(tile=16, img_size=32, resize_src=40,
                              decode_schedule=schedule)
        pipe = DetectionPipeline(cfg, params)
        try:
            key = jax.random.key(1)
            return {"batch": pipe.detect_batch(raw.copy(), key=key),
                    "sharded": pipe.run_batch(raw, key=key)}
        finally:
            pipe.close()

    flat, tuned = run("flat"), run("bb2-ct0-db")
    for eng in ("batch", "sharded"):
        for f in ("message_bits", "ok", "logits"):
            np.testing.assert_array_equal(
                np.asarray(flat[eng][f]), np.asarray(tuned[eng][f]),
                err_msg=f"{eng}/{f}")


def test_config_rejects_bad_schedule():
    from repro.core.detect import DetectionConfig, DetectionPipeline
    params = _params(16, n_bits=DEFAULT_CODE.codeword_bits,
                     channels=4, depth=2)
    with pytest.raises(ValueError):
        DetectionPipeline(
            DetectionConfig(tile=16, img_size=32, resize_src=40,
                            decode_schedule="not-a-schedule"), params)
