"""Pallas RS-decode kernel vs the jax_rs oracle (itself validated against
the numpy Berlekamp-Welch codec): exact agreement on correctable words,
beyond-capacity words, and pure garbage; carry-less GF(16) arithmetic vs
the log/exp tables."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.rs.codec import DEFAULT_CODE, rs_encode
from repro.core.rs import jax_rs
from repro.core.rs.gf import GF
from repro.kernels.rs_decode import _gf16_inv, _gf16_mul, rs_decode_batch


def test_carryless_gf16_mul_matches_tables():
    gf = GF(4)
    a = jnp.arange(16)[:, None] * jnp.ones((1, 16), jnp.int32)
    b = jnp.arange(16)[None, :] * jnp.ones((16, 1), jnp.int32)
    ours = np.asarray(_gf16_mul(a.astype(jnp.int32), b.astype(jnp.int32)))
    ref = gf.mul(np.arange(16)[:, None], np.arange(16)[None, :])
    np.testing.assert_array_equal(ours, ref)


def test_carryless_gf16_inv():
    gf = GF(4)
    a = jnp.arange(1, 16, dtype=jnp.int32)
    ours = np.asarray(_gf16_inv(a))
    np.testing.assert_array_equal(ours, gf.inv(np.arange(1, 16)))
    assert int(_gf16_inv(jnp.int32(0))) == 0  # masked convention


@pytest.mark.parametrize("n_err", [0, 1, 2])
def test_kernel_matches_oracle(n_err):
    rng = np.random.default_rng(n_err)
    code = DEFAULT_CODE
    B = 96
    msgs = rng.integers(0, 2, (B, code.message_bits))
    bad = np.stack([rs_encode(code, m) for m in msgs])
    for i in range(B):
        for s in rng.choice(code.n, n_err, replace=False):
            bad[i, s * code.m + rng.integers(0, code.m)] ^= 1
    ref = jax_rs.make_batch_decoder(code)(jnp.asarray(bad))
    out = rs_decode_batch(jnp.asarray(bad), block=64)
    np.testing.assert_array_equal(np.asarray(out["ok"]),
                                  np.asarray(ref["ok"]))
    np.testing.assert_array_equal(np.asarray(out["message_bits"]),
                                  np.asarray(ref["message_bits"]))
    np.testing.assert_array_equal(np.asarray(out["n_corrected"]),
                                  np.asarray(ref["n_corrected"]))
    if n_err <= code.t:
        assert np.asarray(out["ok"]).all()
        np.testing.assert_array_equal(np.asarray(out["message_bits"]),
                                      msgs)


def test_kernel_garbage_agrees_with_oracle():
    rng = np.random.default_rng(9)
    code = DEFAULT_CODE
    garbage = rng.integers(0, 2, (64, code.codeword_bits))
    ref = jax_rs.make_batch_decoder(code)(jnp.asarray(garbage))
    out = rs_decode_batch(jnp.asarray(garbage), block=64)
    np.testing.assert_array_equal(np.asarray(out["ok"]),
                                  np.asarray(ref["ok"]))


def test_kernel_pads_ragged_batches():
    rng = np.random.default_rng(3)
    code = DEFAULT_CODE
    msgs = rng.integers(0, 2, (13, code.message_bits))  # 13 % 8 != 0
    cws = np.stack([rs_encode(code, m) for m in msgs])
    out = rs_decode_batch(jnp.asarray(cws), block=8)
    assert out["message_bits"].shape == (13, code.message_bits)
    assert np.asarray(out["ok"]).all()


def test_pipeline_device_rs_dispatches_to_pallas_kernel():
    """rs_mode="device" must run the Pallas Berlekamp-Welch kernel for
    the default code, with exact parity against the jax_rs decoder on
    random error patterns at and beyond the correction capacity."""
    from repro.core.detect import make_device_rs
    code = DEFAULT_CODE
    dev = make_device_rs(code)
    # the default code must get the kernel wrapper, not the jax_rs jit
    assert getattr(dev, "__name__", "") == "decode"
    rng = np.random.default_rng(42)
    B = 48
    msgs = rng.integers(0, 2, (B, code.message_bits))
    bad = np.stack([rs_encode(code, m) for m in msgs])
    # mixed per-word error weights: 0 and t (correctable), t+1 and 2t+1
    # (beyond capacity — exercises the failure tie-breaking rule too)
    weights = [0, code.t, code.t + 1, 2 * code.t + 1]
    for i in range(B):
        n_err = weights[i % len(weights)]
        for s in rng.choice(code.n, n_err, replace=False):
            bad[i, s * code.m + rng.integers(0, code.m)] ^= 1
    out = dev(jnp.asarray(bad))
    ref = jax_rs.make_batch_decoder(code)(jnp.asarray(bad))
    for field in ("ok", "message_bits", "n_corrected"):
        np.testing.assert_array_equal(np.asarray(out[field]),
                                      np.asarray(ref[field]), err_msg=field)
    # correctable words recovered exactly
    correctable = np.array([weights[i % len(weights)] <= code.t
                            for i in range(B)])
    assert np.asarray(out["ok"])[correctable].all()
    np.testing.assert_array_equal(
        np.asarray(out["message_bits"])[correctable], msgs[correctable])


def test_make_device_rs_falls_back_for_other_codes():
    from repro.core.detect import make_device_rs
    from repro.core.rs.codec import RSCode
    code = RSCode(m=4, n=15, k=11)
    dev = make_device_rs(code)
    rng = np.random.default_rng(8)
    msgs = rng.integers(0, 2, (6, code.message_bits))
    cws = np.stack([rs_encode(code, m) for m in msgs])
    out = dev(jnp.asarray(cws))
    assert np.asarray(out["ok"]).all()
    np.testing.assert_array_equal(np.asarray(out["message_bits"]), msgs)


def test_non_default_code_falls_back():
    from repro.core.rs.codec import RSCode
    code = RSCode(m=4, n=15, k=11)
    rng = np.random.default_rng(5)
    msgs = rng.integers(0, 2, (8, code.message_bits))
    cws = np.stack([rs_encode(code, m) for m in msgs])
    out = rs_decode_batch(jnp.asarray(cws), code=code)
    assert np.asarray(out["ok"]).all()
