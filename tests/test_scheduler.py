"""Property tests for Algorithm 1 (adaptive stream/lane allocation) and
Algorithm 2 (LPT mini-batch scheduling) invariants.

Hypothesis-based versions run when ``hypothesis`` is installed; seeded-
random equivalents always run."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import allocator, scheduler, tiling
import jax
import jax.numpy as jnp


def mk_profiles(ts, us, oh=1e-4):
    return [allocator.StageProfile(f"s{i}", t, u, oh)
            for i, (t, u) in enumerate(zip(ts, us))]


def _check_allocation_budget_memory(ts, us, B, budget):
    profs = mk_profiles(ts, us)
    cap = 16e9
    alloc = allocator.adaptive_allocation(profs, global_batch=B,
                                          stream_budget=budget, mem_cap=cap)
    assert sum(alloc.streams) <= budget
    assert all(s >= 1 for s in alloc.streams)
    assert allocator.mem_ok(profs, alloc.streams, alloc.minibatch, cap)
    # monotone improvement along the search trace
    js = [j for _, j in alloc.history]
    assert all(js[i + 1] <= js[i] + 1e-12 for i in range(len(js) - 1))


def _check_lpt_conserves(lats, n_lanes):
    tasks = [scheduler.Task(i, n_samples=8, tile=32, lat=l, mem=l * 1e5)
             for i, l in enumerate(lats)]
    total = sum(t.n_samples for t in tasks)
    sched = scheduler.lpt_schedule(tasks, n_lanes=n_lanes,
                                   balance_slack=0.25, mem_cap=1e12,
                                   b_min=1, global_batch=total)
    got = sum(t.n_samples for lane in sched.lanes for t in lane)
    assert got == total
    assert len(sched.lanes) == n_lanes
    assert all(t.minibatch >= 1 for lane in sched.lanes for t in lane)


def _check_tile_offsets_in_bounds(strategy, tile, seed):
    H = W = 64
    key = jax.random.key(seed)
    offs = tiling.tile_offsets(strategy, key, (H, W), tile, 16)
    assert offs.shape == (16, 2)
    assert bool((offs >= 0).all())
    assert bool((offs[:, 0] <= H - tile).all())
    assert bool((offs[:, 1] <= W - tile).all())
    if strategy == "random_grid":
        assert bool((offs % tile == 0).all())
    if strategy == "fixed":
        assert bool((offs == 0).all())


def test_allocation_respects_budget_and_memory_seeded():
    rng = np.random.default_rng(0)
    for _ in range(40):
        _check_allocation_budget_memory(
            rng.uniform(1e-5, 1e-2, 3).tolist(),
            rng.uniform(1e3, 1e7, 3).tolist(),
            int(rng.choice([16, 64, 256])), int(rng.integers(3, 33)))


def test_lpt_schedule_conserves_samples_seeded():
    rng = np.random.default_rng(1)
    for _ in range(30):
        n = int(rng.integers(1, 41))
        _check_lpt_conserves(rng.uniform(1e-4, 1.0, n).tolist(),
                             int(rng.integers(1, 9)))


def test_tile_offsets_in_bounds_seeded():
    rng = np.random.default_rng(2)
    for strategy in tiling.STRATEGIES:
        for tile in (8, 16, 32):
            _check_tile_offsets_in_bounds(strategy, tile,
                                          int(rng.integers(0, 1001)))


def test_per_image_offsets_independent_of_batch():
    """The lane/sharding determinism contract: image i's offset depends
    only on keys[i], so appending pad images changes nothing."""
    base = jax.random.key(5)
    keys8 = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(8))
    keys6 = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(6))
    for strategy in tiling.STRATEGIES:
        o8 = tiling.per_image_offsets(strategy, keys8, (64, 64), 16)
        o6 = tiling.per_image_offsets(strategy, keys6, (64, 64), 16)
        np.testing.assert_array_equal(np.asarray(o8[:6]), np.asarray(o6))
        assert bool((o8 >= 0).all()) and bool((o8 <= 64 - 16).all())
        if strategy == "random_grid":
            assert bool((o8 % 16 == 0).all())


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        ts=st.lists(st.floats(1e-5, 1e-2), min_size=3, max_size=3),
        us=st.lists(st.floats(1e3, 1e7), min_size=3, max_size=3),
        B=st.sampled_from([16, 64, 256]),
        budget=st.integers(3, 32),
    )
    def test_allocation_respects_budget_and_memory(ts, us, B, budget):
        _check_allocation_budget_memory(ts, us, B, budget)


def test_allocation_gives_more_streams_to_bottleneck():
    """The paper's motivating case: a slow RS stage gets the streams.
    The memory cap forces minibatching (m < B), which is the regime where
    stream augmentation has anything to parallelise."""
    profs = mk_profiles([1e-5, 2e-5, 4e-4], [1e4, 1e5, 64.0])
    alloc = allocator.adaptive_allocation(profs, global_batch=256,
                                          stream_budget=18, mem_cap=3.5e6)
    assert alloc.streams[2] > alloc.streams[0]
    assert alloc.streams[2] > alloc.streams[1]


def test_allocation_small_batch_stays_conservative():
    """At tiny batches, launch overhead dominates: the search must not
    blow up the stream counts (the paper's B=16 slowdown case)."""
    profs = mk_profiles([1e-4, 1e-4, 1e-4], [1e4] * 3, oh=5e-3)
    a16 = allocator.adaptive_allocation(profs, global_batch=16,
                                        stream_budget=48, mem_cap=1e9)
    a256 = allocator.adaptive_allocation(profs, global_batch=256,
                                         stream_budget=48, mem_cap=1e9)
    assert sum(a16.streams) <= sum(a256.streams)


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        lats=st.lists(st.floats(1e-4, 1.0), min_size=1, max_size=40),
        n_lanes=st.integers(1, 8),
    )
    def test_lpt_schedule_conserves_samples(lats, n_lanes):
        _check_lpt_conserves(lats, n_lanes)


def test_lpt_balances_loads():
    rng = np.random.default_rng(0)
    tasks = [scheduler.Task(i, 8, 32, float(l), 1.0)
             for i, l in enumerate(rng.uniform(0.1, 1.0, 64))]
    sched = scheduler.lpt_schedule(tasks, n_lanes=4, balance_slack=0.25,
                                   mem_cap=1e12, b_min=1, global_batch=512)
    assert sched.imbalance < 1.6  # LPT bound is 4/3 - 1/(3m) per-load


def test_straggler_monitor_reissues_once():
    import time
    pol = scheduler.StragglerPolicy(timeout_factor=1.0, min_timeout_s=0.01,
                                    max_retries=1)
    mon = scheduler.StragglerMonitor(pol)
    mon.start(1)
    mon.complete(1)
    mon.start(2)  # never completes
    time.sleep(0.05)
    assert mon.stragglers() == [2]
    mon.mark_retried(2)
    assert 2 not in mon.stragglers() or True
    assert mon.complete(2)
    assert not mon.complete(2)  # duplicate completion dropped


# ---------------------------------------------------------------------------
# tiling strategy properties
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        strategy=st.sampled_from(tiling.STRATEGIES),
        tile=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 1000),
    )
    def test_tile_offsets_in_bounds(strategy, tile, seed):
        _check_tile_offsets_in_bounds(strategy, tile, seed)


def test_extract_tiles_matches_manual_slice():
    rng = np.random.default_rng(0)
    imgs = jnp.asarray(rng.uniform(size=(4, 32, 32, 3)).astype(np.float32))
    offs = jnp.asarray([[0, 0], [8, 16], [16, 8], [24, 24]], jnp.int32)
    tiles = tiling.extract_tiles(imgs, offs, 8)
    for i, (y, x) in enumerate(np.asarray(offs)):
        np.testing.assert_array_equal(np.asarray(tiles[i]),
                                      np.asarray(imgs[i, y:y+8, x:x+8]))


def test_grid_partition_reassembles():
    rng = np.random.default_rng(1)
    imgs = jnp.asarray(rng.uniform(size=(2, 32, 32, 3)).astype(np.float32))
    tiles = tiling.grid_partition(imgs, 16)
    assert tiles.shape == (2, 4, 16, 16, 3)
    # tile 0 is the top-left block
    np.testing.assert_array_equal(np.asarray(tiles[:, 0]),
                                  np.asarray(imgs[:, :16, :16]))
